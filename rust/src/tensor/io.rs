//! Checkpoint serialization: a simple length-prefixed binary bundle,
//! plus the versioned stream-state record behind serve hibernation.
//!
//! Bundle format (little-endian):
//!   magic "MACT" | u32 version | u32 count |
//!   per tensor: u32 name_len | name bytes | u32 rank | u64 dims... |
//!               f32 data...
//!
//! Used by coordinator::checkpoint to persist the opaque device-state
//! buffer list between runs (and by tests for golden data).
//!
//! State-record format (little-endian, see [`write_state_record`]):
//!   magic "MACS" | u32 version | u32 feat | u32 dv | u64 step |
//!   z: feat f32 | S: feat*dv f32 | u32 fnv1a-32 checksum
//!
//! The record is the byte-exact `(S, z, step)` snapshot of one
//! [`CausalState`](crate::attn::CausalState): `f32::to_le_bytes` round-
//! trips every bit pattern (including non-finite ones), so a restored
//! stream continues **bit-identically** to one that never left RAM.
//! Everything is validated — magic, version, dimensions, length,
//! checksum — before a single float is written into the caller's
//! state, so a corrupt record can never half-restore a stream.
//!
//! Journal-record format (little-endian, see
//! [`append_journal_record`]):
//!   magic "MACJ" | u32 version | u32 kind | u64 sid |
//!   u32 payload_len | payload bytes | u32 fnv1a-32 checksum
//!
//! One framed record per serve durability event (stream open, prefill,
//! decoded token, close, checkpoint section — the `kind` space belongs
//! to [`crate::serve::durability`]). The frame is self-delimiting, so
//! a journal file is just records back to back; [`read_journal_record`]
//! distinguishes a clean end, a torn tail (truncated or checksum-failed
//! record: recover to the last good record), and structural corruption
//! (bad magic, stale version, absurd length: a typed error, because the
//! file is not trustworthy past that point).

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Result, Write};
use std::path::Path;

use super::Tensor;

const MAGIC: &[u8; 4] = b"MACT";
const VERSION: u32 = 1;

const STATE_MAGIC: &[u8; 4] = b"MACS";
/// Version tag of the stream-state record (bump on layout change; old
/// records are rejected, never misread).
pub const STATE_VERSION: u32 = 1;

pub fn write_bundle(path: &Path, tensors: &[(String, Tensor)]) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        let nb = name.as_bytes();
        w.write_all(&(nb.len() as u32).to_le_bytes())?;
        w.write_all(nb)?;
        w.write_all(&(t.shape.len() as u32).to_le_bytes())?;
        for d in &t.shape {
            w.write_all(&(*d as u64).to_le_bytes())?;
        }
        for x in &t.data {
            w.write_all(&x.to_le_bytes())?;
        }
    }
    Ok(())
}

pub fn read_bundle(path: &Path) -> Result<Vec<(String, Tensor)>> {
    let mut r = BufReader::new(File::open(path)?);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(bad("not a MACT bundle"));
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        return Err(bad(&format!("unsupported bundle version {version}")));
    }
    let count = read_u32(&mut r)? as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = read_u32(&mut r)? as usize;
        if name_len > 1 << 20 {
            return Err(bad("absurd name length"));
        }
        let mut nb = vec![0u8; name_len];
        r.read_exact(&mut nb)?;
        let name = String::from_utf8(nb).map_err(|_| bad("name not utf-8"))?;
        let rank = read_u32(&mut r)? as usize;
        if rank > 16 {
            return Err(bad("absurd rank"));
        }
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(read_u64(&mut r)? as usize);
        }
        // checked: a hostile header can pick dims whose product wraps
        // in release builds (e.g. [2^16; 4] wraps u64/usize to 0) and
        // would sail under the size guard below
        let mut numel: usize = 1;
        for &d in &shape {
            numel = numel
                .checked_mul(d)
                .ok_or_else(|| bad("tensor shape overflows"))?;
        }
        if numel > 1 << 31 {
            return Err(bad("absurd tensor size"));
        }
        let mut bytes = vec![0u8; numel * 4];
        r.read_exact(&mut bytes)?;
        let data = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        out.push((name, Tensor { shape, data }));
    }
    Ok(out)
}

/// Single-tensor convenience wrappers.
pub fn write_tensor(path: &Path, t: &Tensor) -> Result<()> {
    write_bundle(path, &[("t".to_string(), t.clone())])
}

pub fn read_tensor(path: &Path) -> Result<Tensor> {
    let mut v = read_bundle(path)?;
    if v.len() != 1 {
        return Err(bad("expected single-tensor bundle"));
    }
    Ok(v.pop().unwrap().1)
}

/// Exact byte length of a state record for `feat` features and value
/// width `dv`: header (magic + version + feat + dv + step) + payload
/// (`z` then `S`) + trailing checksum.
pub fn state_record_len(feat: usize, dv: usize) -> usize {
    4 + 4 + 4 + 4 + 8 + 4 * feat + 4 * feat * dv + 4
}

/// Serialize a `(S, z, step)` stream snapshot into `buf` (cleared
/// first; capacity is reused across calls, so a warm hibernation arena
/// never reallocates). `s.len()` must be a multiple of `z.len()`.
pub fn write_state_record(buf: &mut Vec<u8>, step: u64, s: &[f32], z: &[f32]) {
    let feat = z.len();
    assert!(feat > 0, "state record needs at least one feature");
    assert_eq!(s.len() % feat, 0, "S is feat x dv");
    let dv = s.len() / feat;
    buf.clear();
    buf.reserve(state_record_len(feat, dv));
    buf.extend_from_slice(STATE_MAGIC);
    buf.extend_from_slice(&STATE_VERSION.to_le_bytes());
    buf.extend_from_slice(&(feat as u32).to_le_bytes());
    buf.extend_from_slice(&(dv as u32).to_le_bytes());
    buf.extend_from_slice(&step.to_le_bytes());
    for x in z {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    for x in s {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    let sum = fnv1a(buf);
    buf.extend_from_slice(&sum.to_le_bytes());
}

/// Deserialize a state record into `s`/`z`, returning the step count.
/// The record is validated in full — magic, version, dimensions,
/// length, checksum — **before** either slice is written, so an error
/// leaves the caller's state untouched.
pub fn read_state_record(bytes: &[u8], s: &mut [f32], z: &mut [f32]) -> Result<u64> {
    let feat = z.len();
    if feat == 0 || s.len() % feat != 0 {
        return Err(bad("state buffers are not feat x dv"));
    }
    let dv = s.len() / feat;
    if bytes.len() != state_record_len(feat, dv) {
        return Err(bad("state record length mismatch"));
    }
    if &bytes[..4] != STATE_MAGIC {
        return Err(bad("not a MACS state record"));
    }
    let word = |at: usize| u32::from_le_bytes(bytes[at..at + 4].try_into().unwrap());
    if word(4) != STATE_VERSION {
        return Err(bad("unsupported state record version"));
    }
    if word(8) as usize != feat || word(12) as usize != dv {
        return Err(bad("state record dims do not match the stream"));
    }
    let body = bytes.len() - 4;
    if fnv1a(&bytes[..body]) != word(body) {
        return Err(bad("state record checksum mismatch"));
    }
    let step = u64::from_le_bytes(bytes[16..24].try_into().unwrap());
    let mut at = 24;
    for x in z.iter_mut() {
        *x = f32::from_le_bytes(bytes[at..at + 4].try_into().unwrap());
        at += 4;
    }
    for x in s.iter_mut() {
        *x = f32::from_le_bytes(bytes[at..at + 4].try_into().unwrap());
        at += 4;
    }
    Ok(step)
}

/// Validate a state record's envelope — magic, version, advertised
/// dims vs byte length, checksum — and return its step count without
/// decoding any floats: the cheap "how many tokens has this stream
/// folded" probe used by serve durability for hibernated streams.
pub fn state_record_step(bytes: &[u8]) -> Result<u64> {
    if bytes.len() < 28 {
        return Err(bad("state record too short"));
    }
    if &bytes[..4] != STATE_MAGIC {
        return Err(bad("not a MACS state record"));
    }
    let word = |at: usize| u32::from_le_bytes(bytes[at..at + 4].try_into().unwrap());
    if word(4) != STATE_VERSION {
        return Err(bad("unsupported state record version"));
    }
    let (feat, dv) = (word(8) as usize, word(12) as usize);
    let payload = feat
        .checked_mul(dv)
        .and_then(|sdv| sdv.checked_add(feat))
        .and_then(|n| n.checked_mul(4))
        .ok_or_else(|| bad("state record dims overflow"))?;
    if feat == 0 || bytes.len() != 24 + payload + 4 {
        return Err(bad("state record length mismatch"));
    }
    let body = bytes.len() - 4;
    if fnv1a(&bytes[..body]) != word(body) {
        return Err(bad("state record checksum mismatch"));
    }
    Ok(u64::from_le_bytes(bytes[16..24].try_into().unwrap()))
}

const JOURNAL_MAGIC: &[u8; 4] = b"MACJ";
/// Version tag of the journal frame (bump on layout change; old
/// journals are rejected with a typed error, never misread).
pub const JOURNAL_VERSION: u32 = 1;
/// Sanity cap on one frame's payload: anything larger is a corrupt
/// length header, not a real record (the biggest real payload is one
/// checkpointed stream state, well under a megabyte).
pub const JOURNAL_MAX_PAYLOAD: usize = 1 << 28;

/// Fixed bytes before the payload: magic + version + kind + sid + len.
const JOURNAL_HEAD: usize = 4 + 4 + 4 + 8 + 4;

/// Total frame length for a `payload_len`-byte payload.
pub fn journal_record_len(payload_len: usize) -> usize {
    JOURNAL_HEAD + payload_len + 4
}

/// Append one framed journal record to `buf` (not cleared: journal
/// writers batch many frames into one buffer between fsyncs). The
/// checksum covers the whole frame, so a torn or bit-flipped write is
/// caught by [`read_journal_record`] before any payload is trusted.
pub fn append_journal_record(buf: &mut Vec<u8>, kind: u32, sid: u64, payload: &[u8]) {
    assert!(payload.len() <= JOURNAL_MAX_PAYLOAD, "journal payload too large");
    let start = buf.len();
    buf.reserve(journal_record_len(payload.len()));
    buf.extend_from_slice(JOURNAL_MAGIC);
    buf.extend_from_slice(&JOURNAL_VERSION.to_le_bytes());
    buf.extend_from_slice(&kind.to_le_bytes());
    buf.extend_from_slice(&sid.to_le_bytes());
    buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    buf.extend_from_slice(payload);
    let sum = fnv1a(&buf[start..]);
    buf.extend_from_slice(&sum.to_le_bytes());
}

/// One parse step over a journal byte stream.
#[derive(Debug)]
pub enum JournalFrame<'a> {
    /// A complete, checksum-clean record; advance by `consumed`.
    Record { kind: u32, sid: u64, payload: &'a [u8], consumed: usize },
    /// The bytes end mid-record or the trailing checksum disagrees: a
    /// torn tail write. Everything before this offset is good.
    Torn,
    /// Clean end of the stream at a record boundary.
    End,
}

/// Parse the journal record starting at `bytes[0]`.
///
/// Returns `Torn` for an incomplete or checksum-failed frame (the
/// recover-to-last-good signal) and a typed [`std::io::Error`] for
/// structural corruption that makes the rest of the file untrustworthy:
/// wrong magic, stale version, or an absurd length header.
pub fn read_journal_record(bytes: &[u8]) -> Result<JournalFrame<'_>> {
    if bytes.is_empty() {
        return Ok(JournalFrame::End);
    }
    if bytes.len() < JOURNAL_HEAD {
        return Ok(JournalFrame::Torn);
    }
    if &bytes[..4] != JOURNAL_MAGIC {
        return Err(bad("not a MACJ journal record"));
    }
    let word = |at: usize| u32::from_le_bytes(bytes[at..at + 4].try_into().unwrap());
    if word(4) != JOURNAL_VERSION {
        return Err(bad("unsupported journal record version"));
    }
    let payload_len = word(20) as usize;
    if payload_len > JOURNAL_MAX_PAYLOAD {
        return Err(bad("journal payload length is absurd"));
    }
    let total = journal_record_len(payload_len);
    if bytes.len() < total {
        return Ok(JournalFrame::Torn);
    }
    if fnv1a(&bytes[..total - 4]) != word(total - 4) {
        return Ok(JournalFrame::Torn);
    }
    Ok(JournalFrame::Record {
        kind: word(8),
        sid: u64::from_le_bytes(bytes[12..20].try_into().unwrap()),
        payload: &bytes[JOURNAL_HEAD..JOURNAL_HEAD + payload_len],
        consumed: total,
    })
}

/// FNV-1a (32-bit) over the record body — cheap corruption tripwire,
/// not a cryptographic seal.
pub(crate) fn fnv1a(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811C_9DC5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn bad(msg: &str) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("macformer_io_test_{name}_{}", std::process::id()));
        p
    }

    #[test]
    fn bundle_round_trip() {
        let path = tmp("rt");
        let tensors = vec![
            ("a".to_string(), Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.])),
            ("b".to_string(), Tensor::from_vec(&[3], vec![-0.5, 0.0, 0.5])),
            ("scalar".to_string(), Tensor::from_vec(&[], vec![7.0])),
        ];
        write_bundle(&path, &tensors).unwrap();
        let back = read_bundle(&path).unwrap();
        assert_eq!(back, tensors);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_garbage() {
        let path = tmp("bad");
        std::fs::write(&path, b"NOTA bundle at all").unwrap();
        assert!(read_bundle(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn single_tensor_helpers() {
        let path = tmp("single");
        let t = Tensor::from_vec(&[4], vec![1., 2., 3., 4.]);
        write_tensor(&path, &t).unwrap();
        assert_eq!(read_tensor(&path).unwrap(), t);
        std::fs::remove_file(&path).unwrap();
    }

    /// Regression: an adversarial header whose dims product wraps the
    /// usize multiply exactly to 0 (2^16 ^ 4 = 2^64) must be rejected,
    /// not silently read as a zero-element tensor with an absurd shape.
    #[test]
    fn rejects_overflowing_shape_header() {
        let path = tmp("overflow");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&VERSION.to_le_bytes());
        bytes.extend_from_slice(&1u32.to_le_bytes()); // one tensor
        bytes.extend_from_slice(&1u32.to_le_bytes()); // name_len
        bytes.push(b'x');
        bytes.extend_from_slice(&4u32.to_le_bytes()); // rank 4
        for _ in 0..4 {
            bytes.extend_from_slice(&(1u64 << 16).to_le_bytes());
        }
        std::fs::write(&path, &bytes).unwrap();
        let err = read_bundle(&path).unwrap_err();
        assert!(err.to_string().contains("overflows"), "{err}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn state_record_round_trips_bit_exactly() {
        let (feat, dv) = (5, 3);
        // include non-finite and signed-zero payloads: hibernation must
        // preserve the exact bit pattern, whatever the fold produced
        let s: Vec<f32> = (0..feat * dv)
            .map(|i| match i % 4 {
                0 => -0.0,
                1 => f32::NAN,
                2 => f32::INFINITY,
                _ => (i as f32).sin() * 1e-3,
            })
            .collect();
        let z: Vec<f32> = (0..feat).map(|i| (i as f32) - 2.5).collect();
        let mut buf = Vec::new();
        write_state_record(&mut buf, 42, &s, &z);
        assert_eq!(buf.len(), state_record_len(feat, dv));
        let mut s2 = vec![0.0f32; feat * dv];
        let mut z2 = vec![0.0f32; feat];
        assert_eq!(read_state_record(&buf, &mut s2, &mut z2).unwrap(), 42);
        for (a, b) in s.iter().zip(&s2) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in z.iter().zip(&z2) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    /// Corrupt/mismatched records fail closed and leave the target
    /// state untouched.
    #[test]
    fn state_record_validates_before_writing() {
        let (feat, dv) = (4, 2);
        let s: Vec<f32> = (0..feat * dv).map(|i| i as f32).collect();
        let z: Vec<f32> = (0..feat).map(|i| 0.5 + i as f32).collect();
        let mut buf = Vec::new();
        write_state_record(&mut buf, 7, &s, &z);

        let sentinel_s = vec![99.0f32; feat * dv];
        let sentinel_z = vec![-99.0f32; feat];
        let check_untouched = |bytes: &[u8], what: &str| {
            let mut s2 = sentinel_s.clone();
            let mut z2 = sentinel_z.clone();
            assert!(read_state_record(bytes, &mut s2, &mut z2).is_err(), "{what}");
            assert_eq!(s2, sentinel_s, "{what} half-wrote S");
            assert_eq!(z2, sentinel_z, "{what} half-wrote z");
        };
        // flipped payload byte -> checksum mismatch
        let mut bitflip = buf.clone();
        bitflip[30] ^= 0x40;
        check_untouched(&bitflip, "bitflip");
        // truncated record
        check_untouched(&buf[..buf.len() - 5], "truncated");
        // wrong magic / version
        let mut magic = buf.clone();
        magic[0] = b'Z';
        check_untouched(&magic, "magic");
        let mut ver = buf.clone();
        ver[4] = 0xFE;
        check_untouched(&ver, "version");
        // dims that disagree with the destination stream
        let mut s_wide = vec![0.0f32; feat * (dv + 1)];
        let mut z_ok = vec![0.0f32; feat];
        assert!(read_state_record(&buf, &mut s_wide, &mut z_ok).is_err());
        // the pristine record still restores
        let mut s2 = sentinel_s.clone();
        let mut z2 = sentinel_z.clone();
        assert_eq!(read_state_record(&buf, &mut s2, &mut z2).unwrap(), 7);
        assert_eq!(s2, s);
        assert_eq!(z2, z);
    }

    #[test]
    fn state_record_step_probe_matches_full_decode() {
        let (feat, dv) = (3, 2);
        let s: Vec<f32> = (0..feat * dv).map(|i| i as f32 * 0.5).collect();
        let z: Vec<f32> = (0..feat).map(|i| i as f32).collect();
        let mut buf = Vec::new();
        write_state_record(&mut buf, 99, &s, &z);
        assert_eq!(state_record_step(&buf).unwrap(), 99);
        // the probe applies the same full validation as the decoder
        let mut flip = buf.clone();
        flip[25] ^= 0x01;
        assert!(state_record_step(&flip).is_err());
        assert!(state_record_step(&buf[..10]).is_err());
        let mut ver = buf.clone();
        ver[4] = 0xFE;
        assert!(state_record_step(&ver).is_err());
    }

    /// One journal buffer holding several frames walks back out intact.
    #[test]
    fn journal_records_round_trip_back_to_back() {
        let mut buf = Vec::new();
        let frames: Vec<(u32, u64, Vec<u8>)> = vec![
            (1, 7, vec![]),
            (3, 7, (0u8..64).collect()),
            (4, 9, vec![0xFF; 5]),
        ];
        for (kind, sid, payload) in &frames {
            append_journal_record(&mut buf, *kind, *sid, payload);
        }
        let mut at = 0;
        for (kind, sid, payload) in &frames {
            match read_journal_record(&buf[at..]).unwrap() {
                JournalFrame::Record { kind: k, sid: s, payload: p, consumed } => {
                    assert_eq!((k, s, p), (*kind, *sid, payload.as_slice()));
                    at += consumed;
                }
                other => panic!("expected a record, got {other:?}"),
            }
        }
        assert!(matches!(read_journal_record(&buf[at..]).unwrap(), JournalFrame::End));
    }

    /// The adversarial journal surface: torn tails recover to the last
    /// good record, structural corruption is a typed error, and none of
    /// it panics.
    #[test]
    fn journal_reader_survives_torn_and_corrupt_tails() {
        let mut buf = Vec::new();
        append_journal_record(&mut buf, 1, 5, b"good");
        let good = buf.len();
        append_journal_record(&mut buf, 3, 5, b"tail payload");

        // truncated tail at every cut point: the first record stays
        // readable, the torn second one reports Torn (never an Err)
        for cut in good + 1..buf.len() {
            let bytes = &buf[..cut];
            let first = read_journal_record(bytes).unwrap();
            let consumed = match first {
                JournalFrame::Record { consumed, payload, .. } => {
                    assert_eq!(payload, b"good");
                    consumed
                }
                other => panic!("first record lost at cut {cut}: {other:?}"),
            };
            assert!(
                matches!(read_journal_record(&bytes[consumed..]).unwrap(), JournalFrame::Torn),
                "cut {cut}"
            );
        }

        // a bit-flipped byte inside the tail record fails its checksum
        // -> Torn (recover to last good), leaving the first frame intact
        let mut flip = buf.clone();
        flip[good + 30] ^= 0x20;
        match read_journal_record(&flip).unwrap() {
            JournalFrame::Record { consumed, .. } => {
                assert!(matches!(
                    read_journal_record(&flip[consumed..]).unwrap(),
                    JournalFrame::Torn
                ));
            }
            other => panic!("{other:?}"),
        }

        // stale version: typed error, not a misread
        let mut ver = buf.clone();
        ver[4] = 0xFE;
        let err = read_journal_record(&ver).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");

        // wrong magic: typed error
        let mut magic = buf.clone();
        magic[0] = b'Z';
        assert!(read_journal_record(&magic).is_err());

        // oversized length header: typed error before any allocation
        let mut huge = buf.clone();
        huge[20..24].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = read_journal_record(&huge).unwrap_err();
        assert!(err.to_string().contains("absurd"), "{err}");
    }
}
