//! Checkpoint serialization: a simple length-prefixed binary bundle.
//!
//! Format (little-endian):
//!   magic "MACT" | u32 version | u32 count |
//!   per tensor: u32 name_len | name bytes | u32 rank | u64 dims... |
//!               f32 data...
//!
//! Used by coordinator::checkpoint to persist the opaque device-state
//! buffer list between runs (and by tests for golden data).

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Result, Write};
use std::path::Path;

use super::Tensor;

const MAGIC: &[u8; 4] = b"MACT";
const VERSION: u32 = 1;

pub fn write_bundle(path: &Path, tensors: &[(String, Tensor)]) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        let nb = name.as_bytes();
        w.write_all(&(nb.len() as u32).to_le_bytes())?;
        w.write_all(nb)?;
        w.write_all(&(t.shape.len() as u32).to_le_bytes())?;
        for d in &t.shape {
            w.write_all(&(*d as u64).to_le_bytes())?;
        }
        for x in &t.data {
            w.write_all(&x.to_le_bytes())?;
        }
    }
    Ok(())
}

pub fn read_bundle(path: &Path) -> Result<Vec<(String, Tensor)>> {
    let mut r = BufReader::new(File::open(path)?);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(bad("not a MACT bundle"));
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        return Err(bad(&format!("unsupported bundle version {version}")));
    }
    let count = read_u32(&mut r)? as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = read_u32(&mut r)? as usize;
        if name_len > 1 << 20 {
            return Err(bad("absurd name length"));
        }
        let mut nb = vec![0u8; name_len];
        r.read_exact(&mut nb)?;
        let name = String::from_utf8(nb).map_err(|_| bad("name not utf-8"))?;
        let rank = read_u32(&mut r)? as usize;
        if rank > 16 {
            return Err(bad("absurd rank"));
        }
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(read_u64(&mut r)? as usize);
        }
        let numel: usize = shape.iter().product();
        if numel > 1 << 31 {
            return Err(bad("absurd tensor size"));
        }
        let mut bytes = vec![0u8; numel * 4];
        r.read_exact(&mut bytes)?;
        let data = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        out.push((name, Tensor { shape, data }));
    }
    Ok(out)
}

/// Single-tensor convenience wrappers.
pub fn write_tensor(path: &Path, t: &Tensor) -> Result<()> {
    write_bundle(path, &[("t".to_string(), t.clone())])
}

pub fn read_tensor(path: &Path) -> Result<Tensor> {
    let mut v = read_bundle(path)?;
    if v.len() != 1 {
        return Err(bad("expected single-tensor bundle"));
    }
    Ok(v.pop().unwrap().1)
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn bad(msg: &str) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("macformer_io_test_{name}_{}", std::process::id()));
        p
    }

    #[test]
    fn bundle_round_trip() {
        let path = tmp("rt");
        let tensors = vec![
            ("a".to_string(), Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.])),
            ("b".to_string(), Tensor::from_vec(&[3], vec![-0.5, 0.0, 0.5])),
            ("scalar".to_string(), Tensor::from_vec(&[], vec![7.0])),
        ];
        write_bundle(&path, &tensors).unwrap();
        let back = read_bundle(&path).unwrap();
        assert_eq!(back, tensors);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_garbage() {
        let path = tmp("bad");
        std::fs::write(&path, b"NOTA bundle at all").unwrap();
        assert!(read_bundle(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn single_tensor_helpers() {
        let path = tmp("single");
        let t = Tensor::from_vec(&[4], vec![1., 2., 3., 4.]);
        write_tensor(&path, &t).unwrap();
        assert_eq!(read_tensor(&path).unwrap(), t);
        std::fs::remove_file(&path).unwrap();
    }
}
