//! Deterministic PRNG: SplitMix64 seeding + xoshiro256** core.
//!
//! Hand-rolled (no `rand` crate offline). Used for all host-side
//! randomness: dataset synthesis, batch shuffling, property-test input
//! generation. Device-side randomness (RMF omega draws) lives in the HLO
//! modules and is threaded via the PRNG key buffers — the two streams are
//! independent by construction.

/// xoshiro256** — 256-bit state, period 2^256 - 1, passes BigCrush.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so nearby seeds give uncorrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent child stream (for per-worker / per-dataset rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire rejection).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        let threshold = n.wrapping_neg() % n;
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_hilo(x, n);
            if lo >= threshold {
                return hi as usize;
            }
        }
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = (self.next_f64().max(1e-12)) as f32;
        let u2 = self.next_f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Rademacher +-1.
    pub fn rademacher(&mut self) -> f32 {
        if self.next_u64() & 1 == 0 { 1.0 } else { -1.0 }
    }

    /// True with probability p.
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Index drawn from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if x < *w {
                return i;
            }
            x -= w;
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }
}

#[inline]
fn mul_hilo(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|s| *s));
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[r.below(10)] += 1;
        }
        for c in counts {
            let f = c as f64 / n as f64;
            assert!((f - 0.1).abs() < 0.01, "bucket freq {f}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(13);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn fork_is_independent() {
        let mut r = Rng::new(1);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
