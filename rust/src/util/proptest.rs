//! Seeded property-testing harness (no proptest crate offline).
//!
//! `check(cases, gen, prop)` draws `cases` random inputs from `gen`, runs
//! `prop`, and on failure performs greedy shrinking via the input's
//! `Shrink` implementation before reporting the minimal counterexample.
//! Deterministic: the failing seed is printed so a case can be replayed
//! with `check_seeded`.

use crate::util::rng::Rng;

/// Types that can propose strictly "smaller" variants of themselves.
pub trait Shrink: Sized + Clone + std::fmt::Debug {
    fn shrinks(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Shrink for f32 {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0.0 {
            out.push(0.0);
            out.push(self / 2.0);
        }
        out
    }
}

impl Shrink for usize {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if !self.is_empty() {
            // drop halves, drop one element, shrink one element
            out.push(self[..self.len() / 2].to_vec());
            out.push(self[self.len() / 2..].to_vec());
            for i in 0..self.len().min(8) {
                let mut v = self.clone();
                v.remove(i);
                out.push(v);
                for s in self[i].shrinks() {
                    let mut v = self.clone();
                    v[i] = s;
                    out.push(v);
                }
            }
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrinks(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrinks()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrinks().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Outcome of a property over one input.
pub type PropResult = Result<(), String>;

/// Run `prop` over `cases` random inputs; panic with the shrunk
/// counterexample on failure. Base seed is fixed for reproducibility;
/// use `check_seeded` to vary it.
pub fn check<T, G, P>(cases: usize, gen: G, prop: P)
where
    T: Shrink,
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> PropResult,
{
    check_seeded(0xC0FFEE, cases, gen, prop)
}

pub fn check_seeded<T, G, P>(seed: u64, cases: usize, gen: G, prop: P)
where
    T: Shrink,
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> PropResult,
{
    for case in 0..cases {
        let mut rng = Rng::new(seed ^ (case as u64).wrapping_mul(0x9E3779B9));
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            let (min, min_msg) = shrink_input(input, msg, &prop);
            panic!(
                "property failed (seed {seed:#x}, case {case}):\n  {min_msg}\n  minimal input: {min:?}"
            );
        }
    }
}

fn shrink_input<T: Shrink, P: Fn(&T) -> PropResult>(
    mut cur: T,
    mut msg: String,
    prop: &P,
) -> (T, String) {
    // Greedy descent, bounded to avoid pathological shrink loops.
    for _ in 0..200 {
        let mut advanced = false;
        for cand in cur.shrinks() {
            if let Err(m) = prop(&cand) {
                cur = cand;
                msg = m;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    (cur, msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_always_true() {
        check(50, |r| r.below(100), |_| Ok(()));
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_and_reports() {
        check(
            50,
            |r| r.below(100) + 1,
            |x| if *x < 1000 { Err("too small".into()) } else { Ok(()) },
        );
    }

    #[test]
    fn shrinks_vec_to_minimal() {
        // property: no vec contains an element >= 5. Shrinker should find
        // a small witness.
        let witness = std::panic::catch_unwind(|| {
            check(
                100,
                |r| (0..r.below(20)).map(|_| r.below(10)).collect::<Vec<usize>>(),
                |v| {
                    if v.iter().any(|x| *x >= 5) {
                        Err("has big element".into())
                    } else {
                        Ok(())
                    }
                },
            )
        });
        assert!(witness.is_err());
    }
}
