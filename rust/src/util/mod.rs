//! Substrate utilities: JSON, CLI, PRNG, logging, property testing.
//!
//! Everything here is hand-rolled because the build is fully offline;
//! see DESIGN.md §System-inventory rows 11-13 and 22.

pub mod cli;
pub mod json;
pub mod logging;
pub mod proptest;
pub mod rng;

/// Peak resident-set size of this process in bytes (`getrusage`).
/// Used by the Table-2 sweep for the paper's "memory" column; each cell
/// runs in its own subprocess so peaks do not contaminate each other.
pub fn peak_rss_bytes() -> u64 {
    // SAFETY: plain libc call with an out-param struct we own.
    unsafe {
        let mut ru: libc::rusage = std::mem::zeroed();
        if libc::getrusage(libc::RUSAGE_SELF, &mut ru) == 0 {
            // ru_maxrss is kilobytes on Linux.
            (ru.ru_maxrss as u64) * 1024
        } else {
            0
        }
    }
}

/// Format a byte count for logs ("1.50 GiB").
pub fn human_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut x = b as f64;
    let mut u = 0;
    while x >= 1024.0 && u < UNITS.len() - 1 {
        x /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{x:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rss_is_positive() {
        assert!(peak_rss_bytes() > 0);
    }

    #[test]
    fn human_bytes_formats() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }
}
