//! Minimal spec-compliant JSON parser + writer.
//!
//! Hand-rolled because the build is fully offline (no serde in the vendor
//! set). Covers everything the stack needs: the artifact manifest written
//! by `python/compile/aot.py`, experiment configs, and metric dumps.
//! Supports the full JSON grammar (nested containers, escapes, unicode
//! `\uXXXX` incl. surrogate pairs, scientific-notation numbers).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON document node. Object keys are sorted (BTreeMap) so output is
/// deterministic — handy for golden tests and diffable metric files.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup; `Value::Null` for missing keys or non-objects.
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    /// Builder helpers.
    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }
    pub fn num(x: impl Into<f64>) -> Value {
        Value::Num(x.into())
    }
}

// ---------------------------------------------------------------------------
// parsing
// ---------------------------------------------------------------------------

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

pub fn parse(text: &str) -> Result<Value, ParseError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal {s}")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            hi
                        };
                        out.push(
                            char::from_u32(cp)
                                .ok_or_else(|| self.err("invalid codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences
                    let len = utf8_len(c);
                    if len == 1 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

// ---------------------------------------------------------------------------
// writing
// ---------------------------------------------------------------------------

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write_value(f, self)
    }
}

fn write_value(f: &mut fmt::Formatter<'_>, v: &Value) -> fmt::Result {
    match v {
        Value::Null => write!(f, "null"),
        Value::Bool(b) => write!(f, "{b}"),
        Value::Num(x) => {
            if !x.is_finite() {
                // JSON has no NaN/Infinity; null is the interoperable choice
                write!(f, "null")
            } else if x.fract() == 0.0 && x.abs() < 1e15 {
                write!(f, "{}", *x as i64)
            } else {
                write!(f, "{x}")
            }
        }
        Value::Str(s) => write_str(f, s),
        Value::Arr(a) => {
            write!(f, "[")?;
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    write!(f, ",")?;
                }
                write_value(f, item)?;
            }
            write!(f, "]")
        }
        Value::Obj(o) => {
            write!(f, "{{")?;
            for (i, (k, val)) in o.iter().enumerate() {
                if i > 0 {
                    write!(f, ",")?;
                }
                write_str(f, k)?;
                write!(f, ":")?;
                write_value(f, val)?;
            }
            write!(f, "}}")
        }
    }
}

fn write_str(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("0").unwrap(), Value::Num(0.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 2);
        assert_eq!(v.get("a").as_arr().unwrap()[1].get("b"), &Value::Null);
        assert_eq!(v.get("c").as_str().unwrap(), "x\ny");
    }

    #[test]
    fn parses_unicode_escapes() {
        assert_eq!(parse(r#""é""#).unwrap(), Value::Str("é".into()));
        // surrogate pair: U+1F600
        assert_eq!(parse(r#""😀""#).unwrap(), Value::Str("😀".into()));
    }

    #[test]
    fn parses_raw_utf8() {
        assert_eq!(parse("\"héllo 😀\"").unwrap(), Value::Str("héllo 😀".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("\"\\q\"").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("{'a': 1}").is_err());
    }

    #[test]
    fn roundtrips() {
        let cases = [
            r#"{"a":[1,2.5,null,true],"b":{"c":"d\ne"}}"#,
            r#"[]"#,
            r#"{}"#,
            r#"[-0.125,1e300]"#,
        ];
        for c in cases {
            let v = parse(c).unwrap();
            let s = v.to_string();
            assert_eq!(parse(&s).unwrap(), v, "case {c}");
        }
    }

    #[test]
    fn nonfinite_serializes_as_null() {
        assert_eq!(Value::Num(f64::NAN).to_string(), "null");
        assert_eq!(Value::Num(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Value::Num(3.0).to_string(), "3");
        assert_eq!(Value::Num(3.25).to_string(), "3.25");
    }

    #[test]
    fn get_on_non_object_is_null() {
        assert_eq!(parse("[1]").unwrap().get("x"), &Value::Null);
    }
}
