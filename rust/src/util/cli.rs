//! Flag parser for the launcher (no clap in the offline vendor set).
//!
//! Grammar: `program <subcommand> [--flag value | --flag=value | --switch]
//! [positional...]`. Typed accessors with defaults; unknown-flag checking
//! happens at the end so subcommands can declare their accepted set.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an explicit token list (testable) — see `from_env`.
    pub fn parse(tokens: &[String]) -> Result<Args, String> {
        let mut out = Args::default();
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if let Some(name) = t.strip_prefix("--") {
                if name.is_empty() {
                    return Err("bare '--' is not supported".into());
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < tokens.len() && !tokens[i + 1].starts_with("--") {
                    out.flags.insert(name.to_string(), tokens[i + 1].clone());
                    i += 1;
                } else {
                    out.switches.push(name.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(t.clone());
            } else {
                out.positional.push(t.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args, String> {
        let tokens: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&tokens)
    }

    fn mark(&self, name: &str) {
        self.consumed.borrow_mut().push(name.to_string());
    }

    pub fn str_flag(&self, name: &str, default: &str) -> String {
        self.mark(name);
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt_flag(&self, name: &str) -> Option<String> {
        self.mark(name);
        self.flags.get(name).cloned()
    }

    pub fn usize_flag(&self, name: &str, default: usize) -> Result<usize, String> {
        self.mark(name);
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn u64_flag(&self, name: &str, default: u64) -> Result<u64, String> {
        self.mark(name);
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn f64_flag(&self, name: &str, default: f64) -> Result<f64, String> {
        self.mark(name);
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name} expects a number, got {v:?}")),
        }
    }

    pub fn switch(&self, name: &str) -> bool {
        self.mark(name);
        self.switches.iter().any(|s| s == name)
            || self.flags.get(name).map(|v| v == "true" || v == "1").unwrap_or(false)
    }

    /// Error on flags nobody asked about (catches typos like --epcohs).
    pub fn check_unknown(&self) -> Result<(), String> {
        let consumed = self.consumed.borrow();
        let unknown: Vec<&String> = self
            .flags
            .keys()
            .chain(self.switches.iter())
            .filter(|k| !consumed.contains(k))
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(format!("unknown flags: {unknown:?}"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_flags_positional() {
        // NOTE the documented ambiguity: `--switch positional` reads the
        // positional as the switch's value, so switches that precede
        // positionals must be written `--switch=true`.
        let a =
            Args::parse(&toks("train --task lra_text --steps=50 --verbose=true file.json"))
                .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.str_flag("task", ""), "lra_text");
        assert_eq!(a.usize_flag("steps", 0).unwrap(), 50);
        assert!(a.switch("verbose"));
        assert_eq!(a.positional, vec!["file.json"]);
        let b = Args::parse(&toks("train file.json --verbose")).unwrap();
        assert!(b.switch("verbose"));
        assert_eq!(b.positional, vec!["file.json"]);
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&toks("bench")).unwrap();
        assert_eq!(a.usize_flag("steps", 7).unwrap(), 7);
        assert_eq!(a.str_flag("task", "x"), "x");
        assert!(!a.switch("verbose"));
    }

    #[test]
    fn type_errors_reported() {
        let a = Args::parse(&toks("x --steps abc")).unwrap();
        assert!(a.usize_flag("steps", 0).is_err());
    }

    #[test]
    fn unknown_flags_detected() {
        let a = Args::parse(&toks("x --known 1 --typo 2")).unwrap();
        let _ = a.usize_flag("known", 0);
        assert!(a.check_unknown().is_err());
        let _ = a.usize_flag("typo", 0);
        assert!(a.check_unknown().is_ok());
    }

    #[test]
    fn switch_followed_by_flag() {
        let a = Args::parse(&toks("x --dry-run --steps 3")).unwrap();
        assert!(a.switch("dry-run"));
        assert_eq!(a.usize_flag("steps", 0).unwrap(), 3);
    }
}
