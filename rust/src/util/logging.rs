//! Tiny stderr logger with wall-clock timestamps and level filtering.
//!
//! Backs the `log` crate facade so library modules can use the standard
//! `log::info!` macros. Level comes from `MACFORMER_LOG` (error|warn|info|
//! debug|trace; default info). Output shape comes from
//! `MACFORMER_LOG_FORMAT`: the default is the human one-liner; `json`
//! switches to one JSON object per line (`ts_s`, `level`, `target`,
//! `msg`, plus `req` when the calling thread is serving an identified
//! request — see [`crate::serve::obs::request_id`]), for log shippers
//! that want structure instead of a regex.

use std::io::Write;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

use once_cell::sync::OnceCell;

use crate::util::json::Value;

static START: OnceCell<Instant> = OnceCell::new();
static JSON_FORMAT: AtomicBool = AtomicBool::new(false);
static LOGGER: Logger = Logger;

struct Logger;

/// The human format: `[    0.123s INFO  target] message`.
fn render_text(ts_s: f64, level: log::Level, target: &str, msg: &str) -> String {
    format!("[{ts_s:9.3}s {level:5} {target}] {msg}")
}

/// The structured format: one JSON object per line. `req` is the
/// current request's id hash (hex), omitted when the thread is not
/// serving an identified request (`req == 0`).
fn render_json(ts_s: f64, level: log::Level, target: &str, msg: &str, req: u64) -> String {
    let mut fields = vec![
        ("ts_s", Value::num(ts_s)),
        ("level", Value::str(level.as_str())),
        ("target", Value::str(target)),
        ("msg", Value::str(msg)),
    ];
    if req != 0 {
        fields.push(("req", Value::str(format!("{req:016x}"))));
    }
    Value::obj(fields).to_string()
}

impl log::Log for Logger {
    fn enabled(&self, metadata: &log::Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &log::Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = START.get().map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0);
        let target = record.target().split("::").last().unwrap_or("");
        let msg = record.args().to_string();
        let line = if JSON_FORMAT.load(Ordering::Relaxed) {
            render_json(t, record.level(), target, &msg, crate::serve::obs::request_id())
        } else {
            render_text(t, record.level(), target, &msg)
        };
        let mut err = std::io::stderr().lock();
        let _ = writeln!(err, "{line}");
    }

    fn flush(&self) {}
}

/// Install the logger; idempotent (subsequent calls are no-ops).
pub fn init() {
    let _ = START.set(Instant::now());
    if matches!(std::env::var("MACFORMER_LOG_FORMAT").as_deref(), Ok("json")) {
        JSON_FORMAT.store(true, Ordering::Relaxed);
    }
    if log::set_logger(&LOGGER).is_ok() {
        let level = match std::env::var("MACFORMER_LOG").as_deref() {
            Ok("error") => log::LevelFilter::Error,
            Ok("warn") => log::LevelFilter::Warn,
            Ok("debug") => log::LevelFilter::Debug,
            Ok("trace") => log::LevelFilter::Trace,
            _ => log::LevelFilter::Info,
        };
        log::set_max_level(level);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke");
    }

    #[test]
    fn text_format_is_the_classic_one_liner() {
        let line = render_text(1.5, log::Level::Info, "serve", "hello");
        assert_eq!(line, "[    1.500s INFO  serve] hello");
    }

    #[test]
    fn json_format_is_one_strict_object_per_line() {
        let line = render_json(0.25, log::Level::Warn, "engine", "queue \"full\"", 0);
        let v = crate::util::json::parse(&line).expect("log line parses as strict JSON");
        assert_eq!(v.get("level").as_str(), Some("WARN"));
        assert_eq!(v.get("target").as_str(), Some("engine"));
        assert_eq!(v.get("msg").as_str(), Some("queue \"full\""));
        assert_eq!(v.get("ts_s").as_f64(), Some(0.25));
        // no request id on the thread -> the key is absent, not zero
        assert!(v.get("req").as_str().is_none());
    }

    #[test]
    fn json_format_carries_the_request_id_when_set() {
        let line = render_json(2.0, log::Level::Info, "http", "served", 0xabcd);
        let v = crate::util::json::parse(&line).expect("log line parses");
        assert_eq!(v.get("req").as_str(), Some("000000000000abcd"));
    }
}
