//! Tiny stderr logger with wall-clock timestamps and level filtering.
//!
//! Backs the `log` crate facade so library modules can use the standard
//! `log::info!` macros. Level comes from `MACFORMER_LOG` (error|warn|info|
//! debug|trace; default info).

use std::io::Write;
use std::time::Instant;

use once_cell::sync::OnceCell;

static START: OnceCell<Instant> = OnceCell::new();
static LOGGER: Logger = Logger;

struct Logger;

impl log::Log for Logger {
    fn enabled(&self, metadata: &log::Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &log::Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = START.get().map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0);
        let mut err = std::io::stderr().lock();
        let _ = writeln!(
            err,
            "[{t:9.3}s {:5} {}] {}",
            record.level(),
            record.target().split("::").last().unwrap_or(""),
            record.args()
        );
    }

    fn flush(&self) {}
}

/// Install the logger; idempotent (subsequent calls are no-ops).
pub fn init() {
    let _ = START.set(Instant::now());
    if log::set_logger(&LOGGER).is_ok() {
        let level = match std::env::var("MACFORMER_LOG").as_deref() {
            Ok("error") => log::LevelFilter::Error,
            Ok("warn") => log::LevelFilter::Warn,
            Ok("debug") => log::LevelFilter::Debug,
            Ok("trace") => log::LevelFilter::Trace,
            _ => log::LevelFilter::Info,
        };
        log::set_max_level(level);
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke");
    }
}
