//! Device-resident model + optimizer state.
//!
//! Training state (params, Adam moments, PRNG key) is an *opaque ordered
//! buffer list* produced by the `<family>.init` module and threaded
//! through `<family>.train` executions entirely on the device. The host
//! never reconstructs the pytree — checkpoints serialize the buffers
//! positionally against the manifest's `param_specs`.

use anyhow::{bail, Result};

use super::executable::{Executable, HostArg};
use super::ModuleInfo;

/// The opaque device-resident training state.
pub struct DeviceState {
    /// params (n_params) followed by optimizer state (n_opt).
    pub state: Vec<xla::PjRtBuffer>,
    /// threaded PRNG key buffer, u32[2]
    pub key: xla::PjRtBuffer,
    pub n_params: usize,
    pub n_opt: usize,
    pub steps_done: u64,
}

impl DeviceState {
    /// Run the init module: seed -> fresh state on device.
    pub fn init(init_exe: &Executable, info: &ModuleInfo, seed: u32) -> Result<DeviceState> {
        let expect = info.n_params + info.n_opt + 1;
        let mut outs = init_exe.run_hosts_untupled(&[HostArg::scalar_u32(seed)], expect)?;
        if outs.len() != expect {
            bail!(
                "{}: init returned {} buffers, expected {} (params {} + opt {} + key)",
                init_exe.name,
                outs.len(),
                expect,
                info.n_params,
                info.n_opt
            );
        }
        let key = outs.pop().unwrap();
        Ok(DeviceState {
            state: outs,
            key,
            n_params: info.n_params,
            n_opt: info.n_opt,
            steps_done: 0,
        })
    }

    /// One train step: state + host batch -> new state; returns the loss
    /// buffer WITHOUT copying it to the host (call `loss_value` when a
    /// reading is actually wanted — usually every k steps).
    pub fn train_step(
        &mut self,
        train_exe: &Executable,
        batch: &[HostArg],
    ) -> Result<xla::PjRtBuffer> {
        self.train_step_buffers(train_exe, {
            let mut bufs = Vec::with_capacity(batch.len());
            for b in batch {
                bufs.push(Executable::upload(b)?);
            }
            bufs
        })
    }

    /// Train step over pre-uploaded batch buffers (hot path; lets callers
    /// overlap staging with execution or reuse pinned batches).
    pub fn train_step_buffers(
        &mut self,
        train_exe: &Executable,
        batch: Vec<xla::PjRtBuffer>,
    ) -> Result<xla::PjRtBuffer> {
        // execute_b borrows buffers, so the state stays owned here and is
        // simply replaced by the returned buffers afterwards.
        let mut args: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(self.state.len() + batch.len() + 1);
        args.extend(self.state.iter());
        args.extend(batch.iter());
        args.push(&self.key);
        let expect = self.n_params + self.n_opt + 2; // state + loss + key
        let mut outs = train_exe.run_buffers_untupled(&args, expect)?;
        if outs.len() != expect {
            bail!(
                "{}: train returned {} buffers, expected {}",
                train_exe.name,
                outs.len(),
                expect
            );
        }
        self.key = outs.pop().unwrap();
        let loss = outs.pop().unwrap();
        self.state = outs;
        self.steps_done += 1;
        Ok(loss)
    }

    /// Fetch a scalar loss buffer to the host.
    pub fn loss_value(loss: &xla::PjRtBuffer) -> Result<f32> {
        Ok(Executable::fetch_f32(loss)?[0])
    }

    /// Borrow the parameter buffers (for eval / generate calls).
    pub fn params(&self) -> &[xla::PjRtBuffer] {
        &self.state[..self.n_params]
    }

    /// Download all state buffers as flat f32 blobs (checkpointing).
    pub fn download(&self) -> Result<Vec<Vec<f32>>> {
        self.state.iter().map(Executable::fetch_f32).collect()
    }

    /// Current key value (for checkpoint).
    pub fn download_key(&self) -> Result<[u32; 2]> {
        let lit = self
            .key
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("key fetch: {e}"))?;
        let v = lit.to_vec::<u32>().map_err(|e| anyhow::anyhow!("{e}"))?;
        Ok([v[0], v[1]])
    }

    /// Rebuild device state from host blobs (checkpoint restore). Shapes
    /// come positionally from the manifest's param_specs ++ opt_specs.
    pub fn restore(
        info: &ModuleInfo,
        blobs: &[Vec<f32>],
        key: [u32; 2],
        steps_done: u64,
    ) -> Result<DeviceState> {
        if blobs.len() != info.n_params + info.n_opt {
            bail!(
                "restore: {} blobs vs manifest {}+{}",
                blobs.len(),
                info.n_params,
                info.n_opt
            );
        }
        let specs = info.param_specs.iter().chain(info.opt_specs.iter());
        let mut state = Vec::with_capacity(blobs.len());
        for (blob, spec) in blobs.iter().zip(specs) {
            if spec.numel() != blob.len() {
                bail!(
                    "restore: blob len {} vs spec {:?} for {}",
                    blob.len(),
                    spec.shape,
                    info.name
                );
            }
            state.push(Executable::upload(&HostArg::F32(
                spec.shape.clone(),
                blob.clone(),
            ))?);
        }
        let key = Executable::upload(&HostArg::key(key))?;
        Ok(DeviceState {
            state,
            key,
            n_params: info.n_params,
            n_opt: info.n_opt,
            steps_done,
        })
    }

    /// Run an eval module: (params..., batch..., key) -> (loss, metric).
    pub fn eval_step(&self, eval_exe: &Executable, batch: &[HostArg]) -> Result<(f32, f32)> {
        let mut args: Vec<xla::PjRtBuffer> = Vec::with_capacity(self.n_params + batch.len() + 1);
        let refs: Vec<&xla::PjRtBuffer> = {
            for b in batch {
                args.push(Executable::upload(b)?);
            }
            args.push(Executable::upload(&HostArg::key([
                0x5EED_u32,
                self.steps_done as u32,
            ]))?);
            self.params().iter().chain(args.iter()).collect()
        };
        let leaves = eval_exe.run_fetch_f32_leaves(&refs)?;
        if leaves.len() != 2 {
            bail!("{}: eval returned {} leaves, expected 2", eval_exe.name, leaves.len());
        }
        Ok((leaves[0][0], leaves[1][0]))
    }

    /// Run a generate module: (params..., prompt, key) -> tokens.
    pub fn generate(
        &self,
        gen_exe: &Executable,
        prompt: &HostArg,
        key: [u32; 2],
    ) -> Result<Vec<i32>> {
        let prompt_buf = Executable::upload(prompt)?;
        let key_buf = Executable::upload(&HostArg::key(key))?;
        let refs: Vec<&xla::PjRtBuffer> = self
            .params()
            .iter()
            .chain([&prompt_buf, &key_buf])
            .collect();
        let outs = gen_exe.run_buffers_ref(&refs)?;
        Executable::fetch_i32(&outs[0])
    }
}
