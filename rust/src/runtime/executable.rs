//! Compiled-module handle: HLO text -> PJRT executable + typed execute
//! helpers over host slices and device buffers.

use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use super::client;

/// A compiled PJRT executable plus bookkeeping.
pub struct Executable {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
    pub compile_seconds: f64,
}

/// Host-side argument for an execution: shape + typed data.
pub enum HostArg {
    F32(Vec<usize>, Vec<f32>),
    I32(Vec<usize>, Vec<i32>),
    U32(Vec<usize>, Vec<u32>),
}

impl HostArg {
    pub fn scalar_u32(x: u32) -> HostArg {
        HostArg::U32(vec![], vec![x])
    }
    pub fn key(k: [u32; 2]) -> HostArg {
        HostArg::U32(vec![2], k.to_vec())
    }
}

impl Executable {
    /// Parse + compile an HLO text file on the global client.
    pub fn compile_file(name: &str, path: &Path) -> Result<Executable> {
        let t0 = Instant::now();
        let client = client::handle()?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e}"))?;
        Ok(Executable {
            name: name.to_string(),
            exe,
            compile_seconds: t0.elapsed().as_secs_f64(),
        })
    }

    /// Upload one host argument to the device.
    pub fn upload(arg: &HostArg) -> Result<xla::PjRtBuffer> {
        let client = client::handle()?;
        let buf = match arg {
            HostArg::F32(dims, data) => client.buffer_from_host_buffer(data, dims, None),
            HostArg::I32(dims, data) => client.buffer_from_host_buffer(data, dims, None),
            HostArg::U32(dims, data) => client.buffer_from_host_buffer(data, dims, None),
        };
        buf.map_err(|e| anyhow!("host->device upload: {e}"))
    }

    /// Execute over device buffers; returns the output buffers (tuple
    /// outputs are decomposed into leaves — see `split_outputs`).
    pub fn run_buffers(&self, args: &[xla::PjRtBuffer]) -> Result<Vec<xla::PjRtBuffer>> {
        let outs = self
            .exe
            .execute_b(args)
            .map_err(|e| anyhow!("execute {}: {e}", self.name))?;
        self.split_outputs(outs)
    }

    /// Execute over borrowed device buffers (hot path — no moves).
    pub fn run_buffers_ref(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::PjRtBuffer>> {
        let outs = self
            .exe
            .execute_b(args)
            .map_err(|e| anyhow!("execute {}: {e}", self.name))?;
        self.split_outputs(outs)
    }

    /// Upload host args, execute, return output buffers.
    pub fn run_hosts(&self, args: &[HostArg]) -> Result<Vec<xla::PjRtBuffer>> {
        let bufs = args.iter().map(Self::upload).collect::<Result<Vec<_>>>()?;
        self.run_buffers(&bufs)
    }

    fn split_outputs(&self, outs: Vec<Vec<xla::PjRtBuffer>>) -> Result<Vec<xla::PjRtBuffer>> {
        let dev0 = outs
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("{}: no output device", self.name))?;
        if dev0.is_empty() {
            bail!("{}: empty output list", self.name);
        }
        Ok(dev0)
    }

    /// Execute and untuple: the vendored xla crate executes with
    /// `untuple_result = false`, so a multi-output module comes back as a
    /// single tuple buffer. This fetches the tuple to the host, splits it,
    /// and re-uploads the leaves — correct everywhere, with a measured
    /// per-step cost recorded in EXPERIMENTS.md §Perf (the state is ~2 MB,
    /// the round-trip is noise next to the step compute on this testbed).
    pub fn run_buffers_untupled(
        &self,
        args: &[&xla::PjRtBuffer],
        expected: usize,
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let outs = self.run_buffers_ref(args)?;
        if outs.len() == expected {
            return Ok(outs);
        }
        if outs.len() != 1 {
            bail!(
                "{}: got {} output buffers, expected {} or 1 tuple",
                self.name,
                outs.len(),
                expected
            );
        }
        let mut lit = outs[0]
            .to_literal_sync()
            .map_err(|e| anyhow!("tuple fetch {}: {e}", self.name))?;
        let leaves = lit
            .decompose_tuple()
            .map_err(|e| anyhow!("tuple decompose {}: {e}", self.name))?;
        if leaves.len() != expected {
            bail!(
                "{}: tuple has {} leaves, expected {}",
                self.name,
                leaves.len(),
                expected
            );
        }
        // NOTE: client.buffer_from_host_literal is NOT used here — the
        // underlying BufferFromHostLiteral transfers asynchronously and the
        // C shim does not await it, so dropping the decomposed Literal
        // races the copy (observed as use-after-free crashes with garbage
        // primitive types). buffer_from_host_buffer uses
        // kImmutableOnlyDuringCall semantics: the copy completes before it
        // returns, making the round-trip sound.
        leaves
            .into_iter()
            .map(|leaf| Self::upload_literal(&leaf))
            .collect()
    }

    /// Sound host re-upload of a (non-tuple) literal; see the note above.
    pub fn upload_literal(leaf: &xla::Literal) -> Result<xla::PjRtBuffer> {
        let client = client::handle()?;
        let dims: Vec<usize> = leaf
            .array_shape()
            .map_err(|e| anyhow!("leaf shape: {e}"))?
            .dims()
            .iter()
            .map(|d| *d as usize)
            .collect();
        let buf = match leaf.ty().map_err(|e| anyhow!("leaf type: {e}"))? {
            xla::ElementType::F32 => {
                let v = leaf.to_vec::<f32>().map_err(|e| anyhow!("{e}"))?;
                client.buffer_from_host_buffer(&v, &dims, None)
            }
            xla::ElementType::S32 => {
                let v = leaf.to_vec::<i32>().map_err(|e| anyhow!("{e}"))?;
                client.buffer_from_host_buffer(&v, &dims, None)
            }
            xla::ElementType::U32 => {
                let v = leaf.to_vec::<u32>().map_err(|e| anyhow!("{e}"))?;
                client.buffer_from_host_buffer(&v, &dims, None)
            }
            xla::ElementType::S64 => {
                let v = leaf.to_vec::<i64>().map_err(|e| anyhow!("{e}"))?;
                client.buffer_from_host_buffer(&v, &dims, None)
            }
            other => bail!("unsupported leaf element type {other:?}"),
        };
        buf.map_err(|e| anyhow!("leaf upload: {e}"))
    }

    /// Upload host args, execute, untuple to `expected` buffers.
    pub fn run_hosts_untupled(
        &self,
        args: &[HostArg],
        expected: usize,
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let bufs = args.iter().map(Self::upload).collect::<Result<Vec<_>>>()?;
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        self.run_buffers_untupled(&refs, expected)
    }

    /// Execute and fetch every output leaf to the host as f32 vectors
    /// (cheapest path for eval-style modules whose outputs are consumed
    /// host-side anyway — no device re-upload).
    pub fn run_fetch_f32_leaves(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<Vec<f32>>> {
        let outs = self.run_buffers_ref(args)?;
        let mut leaves = Vec::new();
        for buf in &outs {
            let mut lit = buf.to_literal_sync().map_err(|e| anyhow!("fetch: {e}"))?;
            match lit.ty() {
                Ok(xla::ElementType::F32) => {
                    leaves.push(lit.to_vec::<f32>().map_err(|e| anyhow!("{e}"))?)
                }
                _ => {
                    for part in lit.decompose_tuple().map_err(|e| anyhow!("{e}"))? {
                        leaves.push(Self::literal_leaves_f32(part)?);
                    }
                }
            }
        }
        Ok(leaves)
    }

    /// Copy a device buffer back as f32 data (flattened).
    pub fn fetch_f32(buf: &xla::PjRtBuffer) -> Result<Vec<f32>> {
        let lit = buf.to_literal_sync().map_err(|e| anyhow!("fetch: {e}"))?;
        Self::literal_leaves_f32(lit)
    }

    /// Copy a device buffer back as i32 data (unwrapping 1-tuples).
    pub fn fetch_i32(buf: &xla::PjRtBuffer) -> Result<Vec<i32>> {
        let lit = buf.to_literal_sync().map_err(|e| anyhow!("fetch: {e}"))?;
        match lit.ty() {
            Ok(xla::ElementType::S32) => {
                lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e}"))
            }
            _ => {
                let mut lit = lit;
                let mut parts = lit.decompose_tuple().map_err(|e| anyhow!("{e}"))?;
                if parts.len() != 1 {
                    bail!("fetch_i32: expected scalar or 1-tuple, got {} parts", parts.len());
                }
                parts
                    .remove(0)
                    .to_vec::<i32>()
                    .map_err(|e| anyhow!("to_vec i32: {e}"))
            }
        }
    }

    /// Flatten a literal (possibly a tuple) into f32 data.
    fn literal_leaves_f32(lit: xla::Literal) -> Result<Vec<f32>> {
        match lit.ty() {
            Ok(xla::ElementType::F32) => {
                lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e}"))
            }
            _ => {
                let mut lit = lit;
                let parts = lit
                    .decompose_tuple()
                    .map_err(|e| anyhow!("decompose: {e}"))?;
                let mut out = Vec::new();
                for p in parts {
                    out.extend(Self::literal_leaves_f32(p)?);
                }
                Ok(out)
            }
        }
    }
}
