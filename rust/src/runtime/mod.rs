//! PJRT runtime: load AOT artifacts and run them from the hot path.
//!
//! `client` owns the process-wide PJRT CPU client, `artifact` parses the
//! manifest contract written by python/compile/aot.py, `executable` wraps
//! compile + execute, and `params` keeps model/optimizer state resident on
//! the device across training steps (the §Perf-critical piece: the host
//! only ever copies the scalar loss back).

pub mod artifact;
pub mod client;
pub mod executable;
pub mod params;

pub use artifact::{ModuleInfo, Registry, TensorSpec};
pub use executable::{Executable, HostArg};
pub use params::DeviceState;
