//! Artifact registry: the typed view of `artifacts/manifest.json`.
//!
//! The manifest is the contract between the Python compile path and this
//! runtime (DESIGN.md §Artifact-contract). The registry exposes module
//! metadata lookups and lazily compiles HLO files into executables.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Value};

/// Shape + dtype of one module input/output.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    fn from_json(v: &Value) -> Result<TensorSpec> {
        let shape = v
            .get("shape")
            .as_arr()
            .ok_or_else(|| anyhow!("spec missing shape"))?
            .iter()
            .map(|d| d.as_usize().unwrap_or(0))
            .collect();
        let dtype = v.get("dtype").as_str().unwrap_or("float32").to_string();
        Ok(TensorSpec { shape, dtype })
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One lowered HLO module's manifest row.
#[derive(Debug, Clone)]
pub struct ModuleInfo {
    pub name: String,
    pub role: String,
    pub task: String,
    pub variant: String,
    pub file: String,
    pub batch: usize,
    pub seq_len: usize,
    pub num_classes: usize,
    pub n_params: usize,
    pub n_opt: usize,
    pub param_specs: Vec<TensorSpec>,
    pub opt_specs: Vec<TensorSpec>,
    pub batch_specs: Vec<(String, TensorSpec)>,
    pub feature_dim: usize,
    pub prompt_len: usize,
    pub max_new: usize,
    pub ppsbn: bool,
}

impl ModuleInfo {
    fn from_json(v: &Value) -> Result<ModuleInfo> {
        let name = v.get("name").as_str().unwrap_or_default().to_string();
        if name.is_empty() {
            bail!("manifest row without name");
        }
        let param_specs = v
            .get("param_specs")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(TensorSpec::from_json)
            .collect::<Result<Vec<_>>>()?;
        let opt_specs = v
            .get("opt_specs")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(TensorSpec::from_json)
            .collect::<Result<Vec<_>>>()?;
        let batch_specs = v
            .get("batch_specs")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(|b| {
                Ok((
                    b.get("name").as_str().unwrap_or("?").to_string(),
                    TensorSpec::from_json(b)?,
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(ModuleInfo {
            role: v.get("role").as_str().unwrap_or_default().to_string(),
            task: v.get("task").as_str().unwrap_or_default().to_string(),
            variant: v.get("variant").as_str().unwrap_or_default().to_string(),
            file: v.get("file").as_str().unwrap_or_default().to_string(),
            batch: v.get("batch").as_usize().unwrap_or(0),
            seq_len: v.get("seq_len").as_usize().unwrap_or(0),
            num_classes: v.get("num_classes").as_usize().unwrap_or(0),
            n_params: v.get("n_params").as_usize().unwrap_or(0),
            n_opt: v.get("n_opt").as_usize().unwrap_or(0),
            feature_dim: v.get("feature_dim").as_usize().unwrap_or(0),
            prompt_len: v.get("prompt_len").as_usize().unwrap_or(0),
            max_new: v.get("max_new").as_usize().unwrap_or(0),
            ppsbn: v.get("config").get("ppsbn").as_bool().unwrap_or(false),
            param_specs,
            opt_specs,
            batch_specs,
            name,
        })
    }

    /// Total parameter (+ optimizer) element count.
    pub fn state_numel(&self) -> usize {
        self.param_specs.iter().map(TensorSpec::numel).sum()
    }
}

/// Parsed manifest + artifact directory.
pub struct Registry {
    pub dir: PathBuf,
    pub modules: BTreeMap<String, ModuleInfo>,
    pub micro_lengths: Vec<usize>,
    pub micro_features: Vec<usize>,
    pub translation_src_max: usize,
    pub translation_seq: usize,
}

impl Registry {
    /// Load `<dir>/manifest.json`.
    pub fn open(dir: &Path) -> Result<Registry> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run `make artifacts` first"))?;
        let root = json::parse(&text).map_err(|e| anyhow!("manifest: {e}"))?;
        let mut modules = BTreeMap::new();
        for row in root.get("modules").as_arr().unwrap_or(&[]) {
            let info = ModuleInfo::from_json(row)?;
            modules.insert(info.name.clone(), info);
        }
        let micro = root.get("micro");
        let arr_usize = |v: &Value| -> Vec<usize> {
            v.as_arr().unwrap_or(&[]).iter().filter_map(|x| x.as_usize()).collect()
        };
        Ok(Registry {
            dir: dir.to_path_buf(),
            micro_lengths: arr_usize(micro.get("lengths")),
            micro_features: arr_usize(micro.get("features")),
            translation_src_max: root.get("translation").get("src_max").as_usize().unwrap_or(24),
            translation_seq: root.get("translation").get("seq").as_usize().unwrap_or(64),
            modules,
        })
    }

    /// Default artifact location: `$MACFORMER_ARTIFACTS` or `./artifacts`.
    pub fn open_default() -> Result<Registry> {
        let dir = std::env::var("MACFORMER_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Registry::open(Path::new(&dir))
    }

    pub fn get(&self, name: &str) -> Result<&ModuleInfo> {
        self.modules.get(name).ok_or_else(|| {
            anyhow!(
                "module {name:?} not in manifest ({} modules known)",
                self.modules.len()
            )
        })
    }

    pub fn hlo_path(&self, info: &ModuleInfo) -> PathBuf {
        self.dir.join(&info.file)
    }

    /// All modules with a given role ("train", "eval", ...).
    pub fn by_role(&self, role: &str) -> Vec<&ModuleInfo> {
        self.modules.values().filter(|m| m.role == role).collect()
    }

    /// The family prefix for one (task, variant): e.g. "lra_text.mac_exp".
    pub fn family(task: &str, variant: &str) -> String {
        format!("{task}.{variant}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_dir() -> PathBuf {
        // tests run from the crate root
        PathBuf::from(
            std::env::var("MACFORMER_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
        )
    }

    /// Artifacts are a build product (`make artifacts`, needs the Python
    /// toolchain); when *absent* these contract tests skip, so `cargo
    /// test` stays meaningful on artifact-less hosts. Artifacts that
    /// exist but fail to parse are a regression and panic — skipping
    /// would turn manifest corruption into a silent green run.
    fn open_or_skip() -> Option<Registry> {
        let dir = manifest_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping artifact contract test: no artifacts at {dir:?}");
            return None;
        }
        Some(Registry::open(&dir).expect("artifacts present but unreadable"))
    }

    #[test]
    fn registry_parses_real_manifest() {
        let Some(reg) = open_or_skip() else { return };
        assert!(reg.modules.len() >= 80, "got {}", reg.modules.len());
        // every Table-2 cell present
        for task in ["lra_text", "lra_listops", "lra_retrieval"] {
            for variant in ["softmax", "rfa", "mac_exp", "mac_inv", "mac_trigh", "mac_log", "mac_sqrt"] {
                for role in ["init", "train", "eval"] {
                    let name = format!("{task}.{variant}.{role}");
                    assert!(reg.modules.contains_key(&name), "missing {name}");
                }
            }
        }
    }

    #[test]
    fn module_files_exist_on_disk() {
        let Some(reg) = open_or_skip() else { return };
        for info in reg.modules.values() {
            assert!(reg.hlo_path(info).exists(), "missing {:?}", info.file);
        }
    }

    #[test]
    fn train_modules_declare_state() {
        let Some(reg) = open_or_skip() else { return };
        for info in reg.by_role("train") {
            assert!(info.n_params > 0, "{}", info.name);
            assert!(info.n_opt > 0, "{}", info.name);
            assert_eq!(info.param_specs.len(), info.n_params, "{}", info.name);
            assert!(!info.batch_specs.is_empty(), "{}", info.name);
        }
    }
}
