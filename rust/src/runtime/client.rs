//! Thread-local PJRT client.
//!
//! The `xla` crate's `PjRtClient` is an `Rc`-backed handle (not `Send`/
//! `Sync`), so a process-global would be unsound under `cargo test`'s
//! thread pool. Each thread lazily creates its own CPU client instead;
//! the launcher is effectively single-threaded over PJRT (parallelism in
//! this stack is *process*-level, via the sweep orchestrator), so in
//! production exactly one client exists.

use anyhow::Result;

thread_local! {
    static CLIENT: std::cell::OnceCell<xla::PjRtClient> =
        const { std::cell::OnceCell::new() };
}

/// Run `f` with this thread's PJRT CPU client (created on first use).
///
/// The client is intentionally *leaked* (an extra Rc clone is forgotten):
/// destroying a TfrtCpuClient tears down process-shared TFRT state and
/// crashes any client created afterwards (observed as SIGSEGV/SIGABRT in
/// sequential test runs). Leaking one client handle per PJRT-touching
/// thread is bounded and safe.
pub fn with<R>(f: impl FnOnce(&xla::PjRtClient) -> Result<R>) -> Result<R> {
    CLIENT.with(|cell| {
        if cell.get().is_none() {
            let c = xla::PjRtClient::cpu()
                .map_err(|e| anyhow::anyhow!("PJRT CPU client init failed: {e}"))?;
            std::mem::forget(c.clone()); // pin: never run the destructor
            let _ = cell.set(c);
        }
        f(cell.get().unwrap())
    })
}

/// Clone this thread's client handle (cheap: bumps an Rc).
pub fn handle() -> Result<xla::PjRtClient> {
    with(|c| Ok(c.clone()))
}

/// Backend description string for logs / `macformer info`.
pub fn describe() -> Result<String> {
    with(|c| {
        Ok(format!(
            "{} ({} device(s), v{})",
            c.platform_name(),
            c.device_count(),
            c.platform_version()
        ))
    })
}
