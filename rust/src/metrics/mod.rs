//! Evaluation metrics: accuracy/perplexity (Table 2, Fig 3), BLEU (Fig 3),
//! NMSE (Fig 4a), and timing statistics (Fig 4b, Table 2, §Perf).

pub mod bleu;

/// Running classification accuracy.
#[derive(Debug, Default, Clone)]
pub struct Accuracy {
    pub correct: f64,
    pub total: f64,
}

impl Accuracy {
    pub fn update(&mut self, correct: f64, total: f64) {
        self.correct += correct;
        self.total += total;
    }
    pub fn value(&self) -> f64 {
        if self.total == 0.0 { 0.0 } else { 100.0 * self.correct / self.total }
    }
}

/// Perplexity from accumulated (token nll sum, token count).
#[derive(Debug, Default, Clone)]
pub struct Perplexity {
    pub nll_sum: f64,
    pub tokens: f64,
}

impl Perplexity {
    pub fn update(&mut self, mean_nll: f64, tokens: f64) {
        self.nll_sum += mean_nll * tokens;
        self.tokens += tokens;
    }
    pub fn value(&self) -> f64 {
        if self.tokens == 0.0 { f64::INFINITY } else { (self.nll_sum / self.tokens).exp() }
    }
    pub fn mean_nll(&self) -> f64 {
        if self.tokens == 0.0 { f64::INFINITY } else { self.nll_sum / self.tokens }
    }
}

/// Normalized mean squared error: mean((a-b)^2) / mean(b^2).
/// Fig 4a reports log10 of this between RMFA and exact attention.
pub fn nmse(approx: &[f32], exact: &[f32]) -> f64 {
    assert_eq!(approx.len(), exact.len());
    assert!(!exact.is_empty());
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (a, b) in approx.iter().zip(exact) {
        let d = (*a - *b) as f64;
        num += d * d;
        den += (*b as f64) * (*b as f64);
    }
    if den == 0.0 { f64::INFINITY } else { num / den }
}

/// Loss EMA for training logs.
#[derive(Debug, Clone)]
pub struct Ema {
    pub alpha: f64,
    pub value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Ema {
        Ema { alpha, value: None }
    }
    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => v + self.alpha * (x - v),
        };
        self.value = Some(v);
        v
    }
}

/// Wall-time statistics over repeated measurements (Fig 4b / §Perf).
#[derive(Debug, Default, Clone)]
pub struct Timing {
    samples: Vec<f64>,
}

impl Timing {
    pub fn push(&mut self, seconds: f64) {
        self.samples.push(seconds);
    }
    pub fn count(&self) -> usize {
        self.samples.len()
    }
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }
    pub fn std(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (self.samples.len() - 1) as f64)
            .sqrt()
    }
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx.min(s.len() - 1)]
    }
    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_accumulates() {
        let mut a = Accuracy::default();
        a.update(3.0, 4.0);
        a.update(1.0, 4.0);
        assert_eq!(a.value(), 50.0);
    }

    #[test]
    fn perplexity_of_uniform() {
        // uniform over 8 classes -> nll = ln 8 -> ppl = 8
        let mut p = Perplexity::default();
        p.update((8.0f64).ln(), 100.0);
        assert!((p.value() - 8.0).abs() < 1e-9);
    }

    #[test]
    fn nmse_zero_for_identical() {
        let a = [1.0, -2.0, 3.0];
        assert_eq!(nmse(&a, &a), 0.0);
    }

    #[test]
    fn nmse_scales_quadratically() {
        let exact = [1.0f32, 1.0, 1.0, 1.0];
        let near: Vec<f32> = exact.iter().map(|x| x + 0.1).collect();
        let far: Vec<f32> = exact.iter().map(|x| x + 0.2).collect();
        let r = nmse(&far, &exact) / nmse(&near, &exact);
        assert!((r - 4.0).abs() < 1e-3, "{r}");
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        for _ in 0..32 {
            e.update(10.0);
        }
        assert!((e.value.unwrap() - 10.0).abs() < 1e-6);
    }

    #[test]
    fn timing_stats() {
        let mut t = Timing::default();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            t.push(x);
        }
        assert_eq!(t.mean(), 3.0);
        assert_eq!(t.min(), 1.0);
        assert_eq!(t.percentile(50.0), 3.0);
        assert!((t.std() - 1.5811).abs() < 1e-3);
    }
}
