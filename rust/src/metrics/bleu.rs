//! Corpus BLEU (Papineni et al. 2002) for the Fig-3 translation toy.
//!
//! Standard BLEU-4: modified n-gram precision with clipping, geometric
//! mean over n=1..4 with +epsilon smoothing for empty counts (method
//! "add-epsilon", needed at toy scale where 4-gram matches can be zero),
//! times the brevity penalty. Operates on integer token ids — the
//! synthetic corpus never needs detokenization.

use std::collections::HashMap;

/// Clipped n-gram match statistics for one sentence pair.
#[derive(Debug, Default, Clone)]
pub struct BleuStats {
    /// matched[n-1], total[n-1] for n = 1..=4
    pub matched: [usize; 4],
    pub total: [usize; 4],
    pub hyp_len: usize,
    pub ref_len: usize,
}

impl BleuStats {
    pub fn accumulate(&mut self, other: &BleuStats) {
        for i in 0..4 {
            self.matched[i] += other.matched[i];
            self.total[i] += other.total[i];
        }
        self.hyp_len += other.hyp_len;
        self.ref_len += other.ref_len;
    }

    /// Corpus BLEU in [0, 100].
    pub fn score(&self) -> f64 {
        if self.hyp_len == 0 {
            return 0.0;
        }
        let mut log_p = 0.0;
        for i in 0..4 {
            let p = if self.total[i] == 0 {
                // sentence shorter than n: skip order (uniform convention)
                continue;
            } else {
                (self.matched[i] as f64 + 1e-9) / self.total[i] as f64
            };
            log_p += p.ln() / 4.0;
        }
        let bp = if self.hyp_len >= self.ref_len {
            1.0
        } else {
            (1.0 - self.ref_len as f64 / self.hyp_len as f64).exp()
        };
        100.0 * bp * log_p.exp()
    }
}

fn ngram_counts(tokens: &[u32], n: usize) -> HashMap<&[u32], usize> {
    let mut map = HashMap::new();
    if tokens.len() >= n {
        for w in tokens.windows(n) {
            *map.entry(w).or_insert(0) += 1;
        }
    }
    map
}

/// Per-sentence statistics (accumulate for corpus BLEU).
pub fn sentence_stats(hyp: &[u32], reference: &[u32]) -> BleuStats {
    let mut s = BleuStats {
        hyp_len: hyp.len(),
        ref_len: reference.len(),
        ..Default::default()
    };
    for n in 1..=4 {
        let h = ngram_counts(hyp, n);
        let r = ngram_counts(reference, n);
        let total: usize = h.values().sum();
        let matched: usize = h
            .iter()
            .map(|(g, c)| (*c).min(r.get(g).copied().unwrap_or(0)))
            .sum();
        s.matched[n - 1] = matched;
        s.total[n - 1] = total;
    }
    s
}

/// Convenience: corpus BLEU over aligned hypothesis/reference lists.
pub fn corpus_bleu(pairs: &[(Vec<u32>, Vec<u32>)]) -> f64 {
    let mut acc = BleuStats::default();
    for (hyp, reference) in pairs {
        acc.accumulate(&sentence_stats(hyp, reference));
    }
    acc.score()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_match_is_100() {
        let s = corpus_bleu(&[(vec![1, 2, 3, 4, 5], vec![1, 2, 3, 4, 5])]);
        assert!((s - 100.0).abs() < 0.01, "{s}");
    }

    #[test]
    fn disjoint_is_zero() {
        let s = corpus_bleu(&[(vec![1, 2, 3, 4, 5], vec![6, 7, 8, 9, 10])]);
        assert!(s < 0.01, "{s}");
    }

    #[test]
    fn partial_overlap_between() {
        let s = corpus_bleu(&[(vec![1, 2, 3, 9, 9], vec![1, 2, 3, 4, 5])]);
        assert!(s > 0.0 && s < 100.0, "{s}");
    }

    #[test]
    fn clipping_prevents_repeat_gaming() {
        // "the the the the" trick: repeated unigram must be clipped.
        let gamed = corpus_bleu(&[(vec![7, 7, 7, 7], vec![7, 1, 2, 3])]);
        let honest = corpus_bleu(&[(vec![7, 1, 2, 9], vec![7, 1, 2, 3])]);
        assert!(honest > gamed, "honest {honest} vs gamed {gamed}");
    }

    #[test]
    fn brevity_penalty_hits_short_hyps() {
        let long = corpus_bleu(&[(vec![1, 2, 3, 4, 5, 6], vec![1, 2, 3, 4, 5, 6])]);
        let short = corpus_bleu(&[(vec![1, 2, 3], vec![1, 2, 3, 4, 5, 6])]);
        assert!(short < long);
    }

    #[test]
    fn hand_computed_unigram_case() {
        // hyp [1,2] vs ref [1,3]: p1 = 1/2, shorter than bigram for n>=2
        // with total 1 each and 0 matches -> heavily penalized but > 0.
        let s = sentence_stats(&[1, 2], &[1, 3]);
        assert_eq!(s.matched[0], 1);
        assert_eq!(s.total[0], 2);
        assert_eq!(s.total[1], 1);
        assert_eq!(s.matched[1], 0);
    }

    #[test]
    fn corpus_pools_statistics() {
        // Corpus BLEU pools counts rather than averaging sentence scores.
        let a = corpus_bleu(&[
            (vec![1, 2, 3, 4], vec![1, 2, 3, 4]),
            (vec![9, 9, 9, 9], vec![5, 6, 7, 8]),
        ]);
        assert!(a > 0.0 && a < 100.0);
    }
}
