//! Integration tests for the observability layer (`serve::obs`) on a
//! live gateway: `/metrics` exposition correctness after a
//! deterministic load, request-id propagation from the HTTP header
//! into the response echo and the exported Chrome trace, and the
//! structural Prometheus invariants (no duplicate family headers,
//! cumulative buckets, `+Inf` == `_count`) re-checked on real output.
//!
//! Global observability state (stage histograms, HTTP class counters,
//! span rings) is process-wide and monotone, so every assertion here
//! is of the "at least"/"never" kind — safe under the test harness's
//! thread-level parallelism.

use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpStream};
use std::time::Duration;

use macformer::serve::net::run_socket;
use macformer::serve::obs;
use macformer::serve::{EngineSpec, LoadConfig, NetConfig, ServeConfig, Server};
use macformer::util::json::Value;

// ---------------------------------------------------------------------------
// fixtures
// ---------------------------------------------------------------------------

/// A small, fast engine shape shared by the obs tests.
fn small_cfg() -> LoadConfig {
    LoadConfig {
        streams: 4,
        tokens: 12,
        prompt: 4,
        head_dim: 8,
        dv: 8,
        num_features: 16,
        min_batch: 2,
        ..LoadConfig::default()
    }
}

fn server_for(cfg: &LoadConfig) -> Server {
    let spec = EngineSpec {
        kernel: cfg.kernel,
        backend: cfg.backend,
        head_dim: cfg.head_dim,
        dv: cfg.dv,
        num_features: cfg.num_features,
        seed: cfg.seed,
    };
    let serve = ServeConfig { min_batch: cfg.min_batch, ..ServeConfig::new(cfg.streams, cfg.dv) };
    Server::start(NetConfig::default(), spec, serve, cfg.resilience.clone(), None)
        .expect("server start")
}

/// One raw request on a fresh connection, read to connection close.
/// Returns `(status, lowercased head, body)`.
fn one_shot(addr: SocketAddr, payload: &[u8]) -> (u16, String, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(10))).expect("read timeout");
    stream.write_all(payload).expect("send request");
    let _ = stream.shutdown(Shutdown::Write);
    let mut buf = Vec::new();
    let _ = stream.read_to_end(&mut buf);
    let text = String::from_utf8_lossy(&buf).into_owned();
    let split = text.find("\r\n\r\n").unwrap_or_else(|| panic!("no response head in {text:?}"));
    let head = text[..split].to_ascii_lowercase();
    let status = head
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("bad status line in {head:?}"));
    (status, head, text[split + 4..].to_string())
}

/// The value of a single unlabelled or exactly-matching series line.
fn series_value(body: &str, prefix: &str) -> u64 {
    body.lines()
        .find_map(|l| l.strip_prefix(prefix))
        .unwrap_or_else(|| panic!("no series line starting with {prefix:?}"))
        .trim()
        .parse()
        .unwrap_or_else(|e| panic!("bad value for {prefix:?}: {e}"))
}

// ---------------------------------------------------------------------------
// /metrics after a deterministic load
// ---------------------------------------------------------------------------

/// The golden family list: every `# HELP` header of a live `/metrics`
/// response, in emission order. A new Telemetry field or stage metric
/// must be added here deliberately, and a dropped family fails loudly.
const FAMILIES: &[&str] = &[
    "macformer_tokens_total",
    "macformer_ticks_total",
    "macformer_idle_ticks_total",
    "macformer_batched_ticks_total",
    "macformer_sequential_ticks_total",
    "macformer_batch_size_sum_total",
    "macformer_queue_depth_sum_total",
    "macformer_admits_total",
    "macformer_rejected_admits_total",
    "macformer_rejected_submits_total",
    "macformer_prefills_total",
    "macformer_prefill_tokens_total",
    "macformer_hibernations_total",
    "macformer_restores_total",
    "macformer_evictions_total",
    "macformer_expirations_total",
    "macformer_shed_total",
    "macformer_faults_total",
    "macformer_quarantines_total",
    "macformer_nonfinite_rejects_total",
    "macformer_batch_max",
    "macformer_queue_depth_max",
    "macformer_active_streams",
    "macformer_hibernated_streams",
    "macformer_decode_jobs",
    "macformer_tick_no",
    "macformer_token_latency_seconds",
    "macformer_stage_duration_seconds",
    "macformer_journal_bytes_total",
    "macformer_recoveries_total",
    "macformer_recovery_replayed_ops_total",
    "macformer_recovery_truncated_bytes_total",
    "macformer_http_responses_total",
];

#[test]
fn metrics_after_a_deterministic_load_is_valid_prometheus_text() {
    let cfg = LoadConfig { verify: false, ..small_cfg() };
    let server = server_for(&cfg);
    let addr = server.local_addr();
    let report = run_socket(&cfg, &addr.to_string()).expect("socket load");
    assert_eq!(report.stream_errors, 0);
    assert_eq!(report.http_5xx, 0);

    let (status, head, body) = one_shot(addr, b"GET /metrics HTTP/1.1\r\nHost: t\r\n\r\n");
    server.shutdown();
    assert_eq!(status, 200);
    assert!(
        head.contains("content-type: text/plain; version=0.0.4"),
        "exposition content type missing: {head}"
    );

    // the golden family list, in order
    let helps: Vec<&str> = body
        .lines()
        .filter_map(|l| l.strip_prefix("# HELP "))
        .map(|rest| rest.split_whitespace().next().unwrap())
        .collect();
    assert_eq!(helps, FAMILIES, "family set or order changed");

    // no duplicate HELP/TYPE headers
    let mut seen = std::collections::HashSet::new();
    for line in body.lines() {
        if line.starts_with("# HELP") || line.starts_with("# TYPE") {
            let key: Vec<&str> = line.split_whitespace().take(3).collect();
            assert!(seen.insert(key.join(" ")), "duplicate header: {line}");
        }
    }

    // the load left its footprint in the hot-path stage histograms
    for stage in ["head_parse", "body_parse", "ingress_wait", "phi_gemm", "state_fold", "sse_write"]
    {
        let prefix = format!("macformer_stage_duration_seconds_count{{stage=\"{stage}\"}} ");
        assert!(series_value(&body, &prefix) > 0, "stage {stage} recorded nothing");
    }
    assert!(series_value(&body, "macformer_tokens_total ") > 0);
    assert!(series_value(&body, "macformer_http_responses_total{class=\"2xx\"} ") > 0);
    // a 5xx would mean the engine failed a request during the load
    assert_eq!(series_value(&body, "macformer_http_responses_total{class=\"5xx\"} "), 0);
    // no durability store behind this server: families present, zero
    assert_eq!(series_value(&body, "macformer_recoveries_total "), 0);

    // histogram invariants on real output: cumulative monotone buckets,
    // +Inf equal to _count, for every labelled stage series
    for stage in macformer::serve::obs::Stage::ALL {
        let tag = format!("stage=\"{}\"", stage.name());
        let mut last = 0u64;
        let mut inf = None;
        for line in body.lines() {
            let Some(rest) = line.strip_prefix("macformer_stage_duration_seconds_bucket{") else {
                continue;
            };
            if !rest.starts_with(tag.as_str()) {
                continue;
            }
            let v: u64 = rest.split('}').nth(1).unwrap().trim().parse().unwrap();
            if rest.contains("le=\"+Inf\"") {
                inf = Some(v);
            } else {
                assert!(v >= last, "non-monotone bucket: {line}");
                last = v;
            }
        }
        let inf = inf.unwrap_or_else(|| panic!("no +Inf bucket for {}", stage.name()));
        let count = series_value(
            &body,
            &format!("macformer_stage_duration_seconds_count{{{tag}}} "),
        );
        assert_eq!(inf, count, "+Inf != _count for {}", stage.name());
        assert!(inf >= last);
    }
}

// ---------------------------------------------------------------------------
// request ids: echoed on the wire, attached to trace spans
// ---------------------------------------------------------------------------

#[test]
fn request_id_is_echoed_and_lands_in_the_exported_trace() {
    let cfg = small_cfg();
    let server = server_for(&cfg);
    let addr = server.local_addr();

    let (status, head, _) = one_shot(
        addr,
        b"GET /healthz HTTP/1.1\r\nHost: t\r\nx-request-id: obs-probe-42\r\n\r\n",
    );
    server.shutdown();
    assert_eq!(status, 200);
    assert!(
        head.contains("x-request-id: obs-probe-42"),
        "request id not echoed: {head}"
    );

    // the span recorded while parsing that request carries the id hash
    let want = format!("{:016x}", obs::hash_request_id(b"obs-probe-42"));
    let trace = obs::trace::chrome_trace_json();
    let doc = macformer::util::json::parse(&trace).expect("trace is strict JSON");
    let events = match doc.get("traceEvents") {
        Value::Arr(events) => events,
        other => panic!("traceEvents is not an array: {other:?}"),
    };
    assert!(!events.is_empty(), "trace has no events");
    let mut saw_meta = false;
    let mut saw_req = false;
    for ev in events {
        match ev.get("ph").as_str() {
            Some("M") => {
                assert_eq!(ev.get("name").as_str(), Some("process_name"));
                saw_meta = true;
            }
            Some("X") => {
                assert!(ev.get("ts").as_f64().is_some(), "X event without ts");
                assert!(ev.get("dur").as_f64().is_some(), "X event without dur");
                if ev.get("args").get("req").as_str() == Some(want.as_str()) {
                    saw_req = true;
                }
            }
            other => panic!("unexpected event phase {other:?}"),
        }
    }
    assert!(saw_meta, "no process_name metadata events");
    assert!(saw_req, "no span carried the request id hash {want}");
}
