//! Fast-vs-oracle equivalence: the `fastpath` tier must reproduce the
//! `reference` tier. The contract is split by SIMD dispatch arm:
//!
//! * **scalar arm** (`MACFORMER_NO_SIMD=1`, or hosts without AVX2+FMA) —
//!   bit-for-bit for the RMF feature map (pure layout change), within
//!   1e-5 for the attention kernels (same math, different blocking);
//! * **AVX2+FMA arm** — everything within 1e-5 (lane-parallel
//!   accumulation reassociates addition);
//! * parallel-vs-sequential stays exact on both arms (same code,
//!   sharded over the persistent pool).
//!
//! CI runs this suite once per arm. Pure host math — no PJRT, safe to
//! run multi-threaded.

use macformer::attn::Kernel;
use macformer::fastpath::{self, simd, FlatRmfMap};
use macformer::reference::{attention, rmf::RmfMap};
use macformer::tensor::Tensor;
use macformer::util::proptest::{check, PropResult};
use macformer::util::rng::Rng;

fn randn(rng: &mut Rng, shape: &[usize], scale: f32) -> Tensor {
    Tensor::randn(rng, shape, scale)
}

/// FlatRmfMap::apply vs RmfMap::apply after conversion, for every
/// Table-1 kernel and shapes down to n=1, D=1: bit-for-bit on the
/// scalar arm, within 1e-5 on the SIMD arm.
#[test]
fn prop_flat_rmf_apply_matches_reference() {
    check(
        40,
        |rng| {
            let kernel_idx = rng.below(5);
            let n = rng.range(1, 9);
            let d = rng.range(1, 10);
            let feat = rng.range(1, 48);
            let seed = rng.next_u64() as f32;
            vec![vec![kernel_idx as f32, n as f32, d as f32, feat as f32, seed]]
        },
        |input: &Vec<Vec<f32>>| -> PropResult {
            let kernel = Kernel::MACLAURIN[input[0][0] as usize % 5];
            let n = (input[0][1] as usize).max(1);
            let d = (input[0][2] as usize).max(1);
            let feat = (input[0][3] as usize).max(1);
            let mut rng = Rng::new(input[0][4] as u64);
            let map = RmfMap::sample(&mut rng, kernel, feat, d, 2.0, 8);
            let flat = FlatRmfMap::from(&map);
            let x = randn(&mut rng, &[n, d], 0.5);
            let a = map.apply(&x);
            let b = flat.apply(&x);
            if a.shape != b.shape {
                return Err(format!("shape {:?} vs {:?}", a.shape, b.shape));
            }
            let simd_arm = simd::active();
            for (i, (p, q)) in a.data.iter().zip(&b.data).enumerate() {
                if simd_arm {
                    // phi values are unnormalized, so scale the 1e-5
                    // contract by magnitude for the rare large features
                    if (p - q).abs() > 1e-5 * p.abs().max(1.0) {
                        return Err(format!(
                            "{kernel} n={n} d={d} D={feat} [simd]: element {i}: {p} vs {q}"
                        ));
                    }
                } else if p.to_bits() != q.to_bits() {
                    return Err(format!(
                        "{kernel} n={n} d={d} D={feat}: element {i}: {p} vs {q} (bits differ)"
                    ));
                }
            }
            Ok(())
        },
    );
}

/// The dispatched GEMMs stay within 1e-5 of their scalar anchors over
/// random shapes — exercised regardless of which arm `active()` picks
/// (on the scalar arm the comparison is trivially exact).
#[test]
fn prop_dispatched_matmuls_match_scalar_anchor() {
    check(
        40,
        |rng| {
            let m = rng.range(1, 12);
            let k = rng.range(1, 40);
            let n = rng.range(1, 12);
            let seed = rng.next_u64() as f32;
            vec![vec![m as f32, k as f32, n as f32, seed]]
        },
        |input: &Vec<Vec<f32>>| -> PropResult {
            let p = &input[0];
            let (m, k, n) =
                ((p[0] as usize).max(1), (p[1] as usize).max(1), (p[2] as usize).max(1));
            let mut rng = Rng::new(p[3] as u64);
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal() * 0.5).collect();
            let b: Vec<f32> = (0..n * k).map(|_| rng.normal() * 0.5).collect();
            let mut anchor = vec![0.0f32; m * n];
            macformer::tensor::matmul_nt_scalar_into(&a, m, k, &b, n, &mut anchor);
            let mut dispatched = vec![f32::NAN; m * n];
            macformer::tensor::matmul_nt_into(&a, m, k, &b, n, &mut dispatched);
            for (i, (x, y)) in anchor.iter().zip(&dispatched).enumerate() {
                if (x - y).abs() > 1e-5 * x.abs().max(1.0) {
                    return Err(format!("nt ({m},{k},{n}) elem {i}: {x} vs {y}"));
                }
            }
            // reuse the same draws for the tn kernel: a as (k x m), b as (k x n)
            let mut anchor_tn = vec![0.0f32; m * n];
            macformer::tensor::matmul_tn_scalar_into(&a, k, m, &b, n, &mut anchor_tn);
            let mut disp_tn = vec![f32::NAN; m * n];
            macformer::tensor::matmul_tn_into(&a, k, m, &b, n, &mut disp_tn);
            for (i, (x, y)) in anchor_tn.iter().zip(&disp_tn).enumerate() {
                if (x - y).abs() > 1e-5 * x.abs().max(1.0) {
                    return Err(format!("tn ({k},{m},{n}) elem {i}: {x} vs {y}"));
                }
            }
            Ok(())
        },
    );
}

/// Fast softmax attention matches the oracle within 1e-5, including
/// non-square m != n (non-causal) and d != dv.
#[test]
fn prop_fast_softmax_matches_oracle() {
    check(
        30,
        |rng| {
            let n = rng.range(1, 12);
            let m = rng.range(1, 12);
            let d = rng.range(1, 8);
            let dv = rng.range(1, 8);
            let causal = rng.below(2);
            let seed = rng.next_u64() as f32;
            vec![vec![n as f32, m as f32, d as f32, dv as f32, causal as f32, seed]]
        },
        |input: &Vec<Vec<f32>>| -> PropResult {
            let p = &input[0];
            let (n, mut m, d, dv) = (
                (p[0] as usize).max(1),
                (p[1] as usize).max(1),
                (p[2] as usize).max(1),
                (p[3] as usize).max(1),
            );
            let causal = p[4] as usize == 1;
            if causal {
                m = n; // causal requires a square prefix structure
            }
            let mut rng = Rng::new(p[5] as u64);
            let q = randn(&mut rng, &[n, d], 0.8);
            let k = randn(&mut rng, &[m, d], 0.8);
            let v = randn(&mut rng, &[m, dv], 1.0);
            let a = attention::softmax_attention(&q, &k, &v, causal);
            let b = fastpath::attention::softmax_attention(&q, &k, &v, causal);
            let diff = a.max_abs_diff(&b);
            if diff > 1e-5 {
                return Err(format!("n={n} m={m} d={d} dv={dv} causal={causal}: diff {diff}"));
            }
            Ok(())
        },
    );
}

/// Fast linear attention matches the oracle within 1e-5, causal and
/// non-causal, with d != dv and n down to 1.
#[test]
fn prop_fast_linear_matches_oracle() {
    check(
        30,
        |rng| {
            let n = rng.range(1, 12);
            let feat = rng.range(1, 10);
            let dv = rng.range(1, 6);
            let causal = rng.below(2);
            let seed = rng.next_u64() as f32;
            vec![vec![n as f32, feat as f32, dv as f32, causal as f32, seed]]
        },
        |input: &Vec<Vec<f32>>| -> PropResult {
            let p = &input[0];
            let (n, feat, dv) = (
                (p[0] as usize).max(1),
                (p[1] as usize).max(1),
                (p[2] as usize).max(1),
            );
            let causal = p[3] as usize == 1;
            let mut rng = Rng::new(p[4] as u64);
            let phi_q = randn(&mut rng, &[n, feat], 1.0).map(f32::abs);
            let phi_k = randn(&mut rng, &[n, feat], 1.0).map(f32::abs);
            let v = randn(&mut rng, &[n, dv], 1.0);
            let a = attention::linear_attention(&phi_q, &phi_k, &v, causal, 1e-6);
            let b = fastpath::attention::linear_attention(&phi_q, &phi_k, &v, causal, 1e-6);
            let diff = a.max_abs_diff(&b);
            if diff > 1e-5 {
                return Err(format!("n={n} feat={feat} dv={dv} causal={causal}: diff {diff}"));
            }
            Ok(())
        },
    );
}

/// Fast kernelized attention matches the oracle within 1e-5 for every
/// Table-1 kernel, causal and non-causal (the causal branch exercises
/// the cols-capped, cols-strided score buffer).
#[test]
fn prop_fast_kernelized_matches_oracle() {
    check(
        25,
        |rng| {
            let kernel_idx = rng.below(5);
            let n = rng.range(1, 10);
            let d = rng.range(1, 6);
            let dv = rng.range(1, 6);
            let causal = rng.below(2);
            let seed = rng.next_u64() as f32;
            vec![vec![
                kernel_idx as f32,
                n as f32,
                d as f32,
                dv as f32,
                causal as f32,
                seed,
            ]]
        },
        |input: &Vec<Vec<f32>>| -> PropResult {
            let p = &input[0];
            let kernel = Kernel::MACLAURIN[p[0] as usize % 5];
            let (n, d, dv) = (
                (p[1] as usize).max(1),
                (p[2] as usize).max(1),
                (p[3] as usize).max(1),
            );
            let causal = p[4] as usize == 1;
            let mut rng = Rng::new(p[5] as u64);
            let q = randn(&mut rng, &[n, d], 0.3);
            let k = randn(&mut rng, &[n, d], 0.3);
            let v = randn(&mut rng, &[n, dv], 1.0);
            let a = attention::kernelized_attention(kernel, &q, &k, &v, causal, 1e-6);
            let b = fastpath::attention::kernelized_attention(kernel, &q, &k, &v, causal, 1e-6);
            let diff = a.max_abs_diff(&b);
            if diff > 1e-5 {
                return Err(format!("{kernel} n={n} d={d} dv={dv} causal={causal}: diff {diff}"));
            }
            Ok(())
        },
    );
}

/// The pooled batched drivers produce EXACTLY the per-problem
/// single-thread results (same kernel code, disjoint output shards),
/// and stay within 1e-5 of the oracle — across g down to 1 (single
/// head), n down to 1, and d != dv.
#[test]
fn prop_parallel_matches_single_thread() {
    check(
        20,
        |rng| {
            let g = rng.range(1, 7);
            let n = rng.range(1, 10);
            let d = rng.range(1, 6);
            let dv = rng.range(1, 6);
            let seed = rng.next_u64() as f32;
            vec![vec![g as f32, n as f32, d as f32, dv as f32, seed]]
        },
        |input: &Vec<Vec<f32>>| -> PropResult {
            let p = &input[0];
            let (g, n, d, dv) = (
                (p[0] as usize).max(1),
                (p[1] as usize).max(1),
                (p[2] as usize).max(1),
                (p[3] as usize).max(1),
            );
            let mut rng = Rng::new(p[4] as u64);
            let q = randn(&mut rng, &[g, n, d], 0.7);
            let k = randn(&mut rng, &[g, n, d], 0.7);
            let v = randn(&mut rng, &[g, n, dv], 1.0);
            let phi_q = q.map(f32::abs);
            let phi_k = k.map(f32::abs);

            let sm = fastpath::softmax_attention_batched(&q, &k, &v, false);
            let kn = fastpath::kernelized_attention_batched(Kernel::Exp, &q, &k, &v, false, 1e-6);
            let la = fastpath::linear_attention_batched(&phi_q, &phi_k, &v, false, 1e-6);
            for gi in 0..g {
                let (qs, ks, vs) = (q.problem2(gi), k.problem2(gi), v.problem2(gi));
                // exact vs the single-thread fast kernel
                let one = fastpath::attention::softmax_attention(&qs, &ks, &vs, false);
                for (a, b) in sm.data[gi * n * dv..(gi + 1) * n * dv].iter().zip(&one.data) {
                    if a.to_bits() != b.to_bits() {
                        return Err(format!("softmax problem {gi}: batched {a} vs single {b}"));
                    }
                }
                // within 1e-5 of the oracle
                let oracle_sm = attention::softmax_attention(&qs, &ks, &vs, false);
                let mut diff = 0.0f32;
                for (a, b) in sm.data[gi * n * dv..(gi + 1) * n * dv]
                    .iter()
                    .zip(&oracle_sm.data)
                {
                    diff = diff.max((a - b).abs());
                }
                if diff > 1e-5 {
                    return Err(format!("softmax problem {gi} vs oracle: diff {diff}"));
                }
                let oracle_kn =
                    attention::kernelized_attention(Kernel::Exp, &qs, &ks, &vs, false, 1e-6);
                let mut diff = 0.0f32;
                for (a, b) in kn.data[gi * n * dv..(gi + 1) * n * dv]
                    .iter()
                    .zip(&oracle_kn.data)
                {
                    diff = diff.max((a - b).abs());
                }
                if diff > 1e-5 {
                    return Err(format!("kernelized problem {gi} vs oracle: diff {diff}"));
                }
                let (pqs, pks) = (phi_q.problem2(gi), phi_k.problem2(gi));
                let oracle_la = attention::linear_attention(&pqs, &pks, &vs, false, 1e-6);
                let mut diff = 0.0f32;
                for (a, b) in la.data[gi * n * dv..(gi + 1) * n * dv]
                    .iter()
                    .zip(&oracle_la.data)
                {
                    diff = diff.max((a - b).abs());
                }
                if diff > 1e-5 {
                    return Err(format!("linear problem {gi} vs oracle: diff {diff}"));
                }
            }
            Ok(())
        },
    );
}

/// Batched phi application equals the sequential FlatRmfMap::apply per
/// problem (and therefore the reference map, by transitivity with the
/// bit-for-bit property above).
#[test]
fn prop_batched_phi_matches_sequential() {
    check(
        20,
        |rng| {
            let g = rng.range(1, 6);
            let n = rng.range(1, 8);
            let d = rng.range(1, 8);
            let feat = rng.range(1, 32);
            let seed = rng.next_u64() as f32;
            vec![vec![g as f32, n as f32, d as f32, feat as f32, seed]]
        },
        |input: &Vec<Vec<f32>>| -> PropResult {
            let p = &input[0];
            let (g, n, d, feat) = (
                (p[0] as usize).max(1),
                (p[1] as usize).max(1),
                (p[2] as usize).max(1),
                (p[3] as usize).max(1),
            );
            let mut rng = Rng::new(p[4] as u64);
            let map = RmfMap::sample(&mut rng, Kernel::Exp, feat, d, 2.0, 8);
            let flat = FlatRmfMap::from(&map);
            let x = randn(&mut rng, &[g, n, d], 0.5);
            let batched = fastpath::apply_map_batched(&flat, &x);
            for gi in 0..g {
                let xs = x.problem2(gi);
                let one = flat.apply(&xs);
                for (i, (a, b)) in batched.data[gi * n * feat..(gi + 1) * n * feat]
                    .iter()
                    .zip(&one.data)
                    .enumerate()
                {
                    if a.to_bits() != b.to_bits() {
                        return Err(format!("problem {gi} element {i}: {a} vs {b}"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Deterministic spot check of the smallest shapes the batched drivers
/// must handle: one problem, one row, d != dv.
#[test]
fn single_problem_single_row_nonsquare() {
    let mut rng = Rng::new(0xE1);
    let q = randn(&mut rng, &[1, 1, 3], 0.5);
    let k = randn(&mut rng, &[1, 1, 3], 0.5);
    let v = randn(&mut rng, &[1, 1, 5], 1.0);
    let out = fastpath::softmax_attention_batched(&q, &k, &v, true);
    assert_eq!(out.shape, vec![1, 1, 5]);
    // one key => attention output copies v exactly (weight 1)
    for (o, x) in out.data.iter().zip(&v.data) {
        assert!((o - x).abs() < 1e-6, "{o} vs {x}");
    }
}
