//! End-to-end training integration: init -> train steps -> eval ->
//! checkpoint round-trip, on a real compiled artifact.
//!
//! One #[test] = one process = one PJRT client (see pjrt_smoke.rs).
//! Uses the smallest artifact family (translation, n=64) so the test
//! stays fast while exercising every DeviceState path.

mod common;

use common::registry_or_skip;
use macformer::config::RunConfig;
use macformer::coordinator::{checkpoint, Trainer};
use macformer::runtime::DeviceState;

#[test]
fn training_loop_end_to_end() {
    let Some(reg) = registry_or_skip() else { return };
    let cfg = RunConfig {
        task: "translation".into(),
        variant: "softmax".into(),
        suffix: ".ppsbn".into(),
        seed: 7,
        train_examples: 128,
        eval_examples: 64,
        steps: 6,
        eval_every: 100,
        log_every: 2,
        ..RunConfig::default()
    };
    let mut tr = Trainer::build(cfg, &reg).unwrap();

    // --- losses decrease over a short run (toy task is easy) -------------
    let first = DeviceState::loss_value(&tr.step().unwrap()).unwrap();
    assert!(first.is_finite(), "first loss {first}");
    let report = tr.run().unwrap();
    assert_eq!(report.steps, 6);
    assert!(report.final_loss.is_finite());
    assert!(
        report.final_loss < first as f64 * 1.5,
        "loss exploded: {first} -> {}",
        report.final_loss
    );
    // eval produced BLEU in [0, 100] and a perplexity > 1
    assert!((0.0..=100.0).contains(&report.quality), "{}", report.quality);
    assert!(report.perplexity > 1.0);

    // --- deterministic re-init: same seed + same batch, same loss ---------
    use macformer::coordinator::TaskData;
    let data = TaskData::build("translation", 11, 64, tr.info.seq_len, 24).unwrap();
    let idx: Vec<usize> = (0..tr.info.batch).collect();
    let batch = data.stage(&idx, tr.info.seq_len);
    tr.reinit(7).unwrap();
    let again = DeviceState::loss_value(&tr.step_with(&batch).unwrap()).unwrap();
    tr.reinit(7).unwrap();
    let batch2 = data.stage(&idx, tr.info.seq_len);
    let again2 = DeviceState::loss_value(&tr.step_with(&batch2).unwrap()).unwrap();
    assert_eq!(again, again2, "same seed must give identical first step");

    // --- checkpoint round-trip --------------------------------------------
    let path = std::env::temp_dir().join(format!("mac_ckpt_{}.mact", std::process::id()));
    checkpoint::save(&path, &tr.state, &tr.info).unwrap();
    let restored = checkpoint::load(&path, &tr.info).unwrap();
    assert_eq!(restored.n_params, tr.state.n_params);
    assert_eq!(restored.steps_done, tr.state.steps_done);
    let a = tr.state.download().unwrap();
    let b = restored.download().unwrap();
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x, y, "restored state differs");
    }
    assert_eq!(
        tr.state.download_key().unwrap(),
        restored.download_key().unwrap()
    );
    std::fs::remove_file(&path).ok();
}
