//! PJRT runtime smoke tests.
//!
//! TfrtCpuClient instances share process-global TFRT state; creating
//! clients on several test threads (cargo test spawns one thread per
//! #[test]) is unreliable. Each integration-test FILE is its own process,
//! and this file keeps all PJRT work inside ONE #[test] so exactly one
//! client exists. The same policy applies to the other pjrt_*.rs files.

mod common;

use macformer::runtime::{client, Executable, HostArg};

const TWO_OUT_HLO: &str = r#"
HloModule two_out, entry_computation_layout={(f32[2]{0}, f32[2]{0})->(f32[2]{0}, f32[2]{0})}

ENTRY main.5 {
  Arg_0.1 = f32[2]{0} parameter(0)
  one = f32[] constant(1)
  ones = f32[2]{0} broadcast(one), dimensions={}
  add.1 = f32[2]{0} add(Arg_0.1, ones)
  Arg_1.2 = f32[2]{0} parameter(1)
  two = f32[] constant(2)
  twos = f32[2]{0} broadcast(two), dimensions={}
  multiply.1 = f32[2]{0} multiply(Arg_1.2, twos)
  ROOT tuple.1 = (f32[2]{0}, f32[2]{0}) tuple(add.1, multiply.1)
}
"#;

#[test]
fn pjrt_smoke() {
    // Two-tier gating: on stub-backend builds this device-tier test
    // skips (the host fastpath tests carry coverage there); a real
    // PJRT backend failing to initialize panics inside the gate.
    if !common::pjrt_or_skip() {
        return;
    }
    // -- client ------------------------------------------------------------
    client::with(|c| {
        assert_eq!(c.platform_name(), "cpu");
        assert!(c.device_count() >= 1);
        Ok(())
    })
    .unwrap();

    let dir = std::env::temp_dir().join(format!("mac_pjrt_smoke_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("two_out.hlo.txt");
    std::fs::write(&path, TWO_OUT_HLO).unwrap();
    let exe = Executable::compile_file("two_out", &path).unwrap();

    // -- raw execute returns ONE tuple buffer (untuple_result=false) -------
    let raw = exe
        .run_hosts(&[
            HostArg::F32(vec![2], vec![1.0, 2.0]),
            HostArg::F32(vec![2], vec![3.0, 4.0]),
        ])
        .unwrap();
    assert_eq!(raw.len(), 1, "expected a single tuple output buffer");

    // -- run_hosts_untupled splits it into addressable leaves --------------
    let outs = exe
        .run_hosts_untupled(
            &[
                HostArg::F32(vec![2], vec![1.0, 2.0]),
                HostArg::F32(vec![2], vec![3.0, 4.0]),
            ],
            2,
        )
        .unwrap();
    assert_eq!(outs.len(), 2);
    assert_eq!(Executable::fetch_f32(&outs[0]).unwrap(), vec![2.0, 3.0]);
    assert_eq!(Executable::fetch_f32(&outs[1]).unwrap(), vec![6.0, 8.0]);

    // -- untupled output buffers can feed the next execution ----------------
    // f(x, y) = (x + 1, 2y): thread x through 5 iterations
    let mut buf = exe
        .run_hosts_untupled(
            &[
                HostArg::F32(vec![2], vec![0.0, 10.0]),
                HostArg::F32(vec![2], vec![0.0, 0.0]),
            ],
            2,
        )
        .unwrap()
        .remove(0);
    let zeros = Executable::upload(&HostArg::F32(vec![2], vec![0.0, 0.0])).unwrap();
    for _ in 0..5 {
        buf = exe.run_buffers_untupled(&[&buf, &zeros], 2).unwrap().remove(0);
    }
    assert_eq!(Executable::fetch_f32(&buf).unwrap(), vec![6.0, 16.0]);

    // -- fetch_f32 flattens tuples ------------------------------------------
    let flat = Executable::fetch_f32(&raw[0]).unwrap();
    assert_eq!(flat, vec![2.0, 3.0, 6.0, 8.0]);

    std::fs::remove_dir_all(&dir).ok();
}
