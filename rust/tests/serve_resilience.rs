//! Integration coverage for the serve resilience layer through the
//! public API only: hibernation round trips are bit-identical (with a
//! real disk spill directory), retired slots leak no latency residue
//! into their successors (the telemetry-correctness fix), and the
//! [`ServeError`] contract (Display / `code()` / `is_retryable()`) is
//! pinned by an exhaustive match, so adding a variant without extending
//! the contract table is a compile error here.

use macformer::attn::{AttentionSession, AttentionSpec, Backend, Kernel};
use macformer::serve::{
    ResilienceConfig, Scheduler, ServeConfig, ServeError, SpillMode, StreamPool, StreamStatus,
    Supervisor,
};
use macformer::util::rng::Rng;

fn session(seed: u64) -> AttentionSession {
    AttentionSpec::new(Kernel::Exp)
        .head_dim(5)
        .num_features(16)
        .causal(true)
        .seed(seed)
        .backend(Backend::HostFast)
        .build()
        .unwrap()
}

/// Two streams fed identical tokens; one hibernates to a real spill
/// directory twice mid-decode while the other never leaves its slot.
/// Every output must match bit for bit — the snapshot/restore cycle
/// (versioned record, file round trip, state rebuild) must be exact,
/// not approximate — and the spill directory must hold a record file
/// exactly while the stream is hibernated.
#[test]
fn disk_hibernation_round_trip_is_bit_identical_mid_decode() {
    let dir = std::env::temp_dir().join(format!("macformer_resil_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let sess = session(31);
    let resilience =
        ResilienceConfig { spill: SpillMode::Disk(dir.clone()), ..ResilienceConfig::default() };
    let mut sup = Supervisor::new(&sess, ServeConfig::new(2, 3), resilience).unwrap();

    let control = sup.open().unwrap();
    let roamer = sup.open().unwrap();
    assert_eq!(sup.status(control), Ok(StreamStatus::Active));

    let mut rng = Rng::new(77);
    let mut out_c = [0.0f32; 3];
    let mut out_r = [0.0f32; 3];
    for t in 0..10 {
        let q: Vec<f32> = (0..5).map(|_| rng.normal() * 0.5).collect();
        let k: Vec<f32> = (0..5).map(|_| rng.normal() * 0.5).collect();
        let v: Vec<f32> = (0..3).map(|_| rng.normal()).collect();
        // the roamer's submit transparently restores it when hibernated
        sup.submit(control, &q, &k, &v).unwrap();
        sup.submit(roamer, &q, &k, &v).unwrap();
        sup.tick().unwrap();
        sup.take_output(control, &mut out_c).unwrap();
        sup.take_output(roamer, &mut out_r).unwrap();
        for (a, b) in out_c.iter().zip(&out_r) {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "token {t}: hibernated stream diverged ({a} vs {b})"
            );
        }
        if t == 3 || t == 6 {
            sup.hibernate(roamer).unwrap();
            assert_eq!(sup.status(roamer), Ok(StreamStatus::Hibernated));
            assert_eq!(sup.hibernated_streams(), 1);
            assert_eq!(sup.active_streams(), 1);
            let files = std::fs::read_dir(&dir).unwrap().count();
            assert_eq!(files, 1, "one spill file while hibernated");
        }
    }
    assert_eq!(sup.telemetry().hibernations(), 2);
    assert_eq!(sup.telemetry().restores(), 2);
    let files = std::fs::read_dir(&dir).unwrap().count();
    assert_eq!(files, 0, "restore reclaims the spill file");
    sup.close(control).unwrap();
    sup.close(roamer).unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// A retired stream's submit timestamp must not leak into the latency
/// accounting of the stream that reuses its slot. The first stream
/// submits and then sits un-ticked for ~150ms before being retired; the
/// successor submits and is served immediately — so the histogram's max
/// must reflect only the successor's microseconds, not the orphaned
/// 150ms.
#[test]
fn retired_slot_leaks_no_latency_residue_into_its_successor() {
    let sess = session(32);
    let mut pool = StreamPool::new(&sess, ServeConfig::new(1, 2)).unwrap();
    let mut scheduler = Scheduler::new();

    let orphan = pool.admit().unwrap();
    pool.submit(orphan, &[0.1; 5], &[0.2; 5], &[1.0, -1.0]).unwrap();
    std::thread::sleep(std::time::Duration::from_millis(150));
    // retire with the token still pending: the submission is dropped
    // un-served, so its age must never reach the histogram
    pool.retire(orphan).unwrap();

    let heir = pool.admit().unwrap();
    pool.submit(heir, &[0.1; 5], &[0.2; 5], &[1.0, -1.0]).unwrap();
    scheduler.tick(&mut pool).unwrap();
    let mut out = [0.0f32; 2];
    pool.take_output(heir, &mut out).unwrap();

    let tel = pool.telemetry();
    assert_eq!(tel.tokens(), 1, "only the heir's token was served");
    assert!(
        tel.latency_max() < 0.1,
        "stale submit timestamp leaked into latency: max {}s",
        tel.latency_max()
    );
    pool.retire(heir).unwrap();
}

/// Every [`ServeError`] variant's wire contract in one table: stable
/// `code()`, `is_retryable()` verdict, and a Display phrase. The match
/// below lists every variant by name — no wildcard — so a new variant
/// fails compilation here until the table (and the wire mapping it
/// pins) is extended.
#[test]
fn serve_error_contract_is_exhaustive_and_stable() {
    let cases: Vec<(ServeError, &str, bool, &str)> = vec![
        (
            ServeError::InvalidConfig { what: "max_streams must be > 0" },
            "invalid_config",
            false,
            "invalid serve config",
        ),
        (ServeError::PoolFull { capacity: 4 }, "pool_full", true, "pool full"),
        (
            ServeError::Backpressure { max_pending: 8, retry_after_ticks: 1 },
            "backpressure",
            true,
            "backpressure",
        ),
        (ServeError::UnknownStream, "unknown_stream", false, "unknown stream"),
        (ServeError::StreamBusy, "stream_busy", true, "stream busy"),
        (ServeError::NoOutput, "no_output", true, "no output"),
        (
            ServeError::BadRow { what: "q", expected: 5, got: 3 },
            "bad_row",
            false,
            "bad q row",
        ),
        (ServeError::NonFinite { what: "v" }, "non_finite", false, "non-finite v"),
        (ServeError::Expired, "expired", false, "expired"),
        (ServeError::Faulted, "faulted", false, "faulted"),
        (ServeError::Session("backend refused".into()), "session", false, "backend refused"),
    ];
    for (err, code, retryable, phrase) in &cases {
        // exhaustiveness guard: every variant, no `_` arm
        match err {
            ServeError::InvalidConfig { .. } => {}
            ServeError::PoolFull { .. } => {}
            ServeError::Backpressure { .. } => {}
            ServeError::UnknownStream => {}
            ServeError::StreamBusy => {}
            ServeError::NoOutput => {}
            ServeError::BadRow { .. } => {}
            ServeError::NonFinite { .. } => {}
            ServeError::Expired => {}
            ServeError::Faulted => {}
            ServeError::Session(_) => {}
        }
        assert_eq!(err.code(), *code);
        assert_eq!(err.is_retryable(), *retryable, "{code}");
        let rendered = err.to_string();
        assert!(rendered.contains(phrase), "{code}: {rendered:?} missing {phrase:?}");
        // the trait-object path (anyhow interop) renders identically
        let dynamic: &dyn std::error::Error = err;
        assert_eq!(dynamic.to_string(), rendered);
    }
    // one code per variant, and the table covers all eleven
    let mut codes: Vec<&str> = cases.iter().map(|c| c.1).collect();
    codes.sort_unstable();
    codes.dedup();
    assert_eq!(codes.len(), cases.len());
}
