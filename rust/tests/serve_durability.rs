//! Integration tests for durable serve: the write-ahead journal and
//! checkpoint store (`serve::durability`) wired through the HTTP
//! gateway.
//!
//! Three scenarios:
//!
//! 1. Crash-restart: a server is stopped abruptly (no drain, no final
//!    checkpoint — exactly what `SIGKILL` looks like to the store),
//!    restarted on the same data dir, and must answer the resume probe
//!    for every acked stream and fold new rows **bit-identically** to
//!    a reference server that never died.
//! 2. Graceful drain: [`Server::drain`] leaves a final checkpoint that
//!    a restart recovers from, with the journal fully subsumed.
//! 3. Corruption: a bit-flipped checkpoint is a typed startup error,
//!    never a partial recovery.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::time::Duration;

use macformer::serve::{DurabilityConfig, EngineSpec, LoadConfig, NetConfig, ServeConfig, Server};

/// head_dim == dv for these shapes.
const DIMS: usize = 8;
/// Rows per prefill batch.
const ROWS: usize = 4;

fn data_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("macformer_durable_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn spec() -> EngineSpec {
    let cfg = LoadConfig::default();
    EngineSpec {
        kernel: cfg.kernel,
        backend: cfg.backend,
        head_dim: DIMS,
        dv: DIMS,
        num_features: 16,
        seed: 7,
    }
}

/// Start a gateway; `dir` turns durability on with tick-level sync, so
/// an abrupt stop loses nothing that was acked.
fn start(dir: Option<&Path>) -> Server {
    let durability =
        dir.map(|d| DurabilityConfig { sync_every_ticks: 0, ..DurabilityConfig::new(d) });
    let serve = ServeConfig::new(8, DIMS);
    Server::start(NetConfig::default(), spec(), serve, Default::default(), durability)
        .expect("server start")
}

/// A minimal keep-alive HTTP client (Content-Length framing only; the
/// routes used here never answer chunked).
struct Client {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect");
        stream.set_read_timeout(Some(Duration::from_secs(10))).expect("read timeout");
        let _ = stream.set_nodelay(true);
        Client { stream, buf: Vec::new() }
    }

    /// One request on the persistent connection: `(status, body)`.
    fn request(&mut self, method: &str, path: &str, body: &str) -> (u16, String) {
        let req = format!(
            "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        self.stream.write_all(req.as_bytes()).expect("send request");
        let head_end = loop {
            if let Some(pos) = self.buf.windows(4).position(|w| w == b"\r\n\r\n") {
                break pos + 4;
            }
            self.read_more();
        };
        let head = String::from_utf8_lossy(&self.buf[..head_end]).to_ascii_lowercase();
        let status: u16 = head
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .unwrap_or_else(|| panic!("bad status line in {head:?}"));
        let len: usize = head
            .lines()
            .find_map(|l| l.strip_prefix("content-length:"))
            .map(|v| v.trim().parse().expect("content-length"))
            .unwrap_or(0);
        while self.buf.len() < head_end + len {
            self.read_more();
        }
        let body = String::from_utf8_lossy(&self.buf[head_end..head_end + len]).into_owned();
        self.buf.drain(..head_end + len);
        (status, body)
    }

    fn read_more(&mut self) {
        let mut chunk = [0u8; 4096];
        let n = self.stream.read(&mut chunk).expect("read response");
        assert!(n > 0, "server closed mid-response");
        self.buf.extend_from_slice(&chunk[..n]);
    }
}

/// Deterministic small-integer token rows: identical JSON on every
/// server, so response bodies compare byte-for-byte.
fn rows_json(salt: i32) -> String {
    let mut s = String::from("[");
    for i in 0..(ROWS * DIMS) as i32 {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&(((salt + i) % 3) - 1).to_string());
    }
    s.push(']');
    s
}

/// One prefill batch of [`ROWS`] q/k/v rows.
fn batch(salt: i32) -> String {
    let (q, k, v) = (rows_json(salt), rows_json(salt + 1), rows_json(salt + 2));
    format!("{{\"q\":{q},\"k\":{k},\"v\":{v}}}")
}

#[test]
fn crash_restart_recovers_streams_bit_identically_over_the_socket() {
    let dir = data_dir("crash");

    // reference run: a server that never dies folds both batches
    let reference = start(None);
    let mut c = Client::connect(reference.local_addr());
    let (status, body) = c.request("POST", "/v1/streams", "{}");
    assert_eq!(status, 201, "{body}");
    let (status, ref_out1) = c.request("POST", "/v1/streams/s-1/prefill", &batch(1));
    assert_eq!(status, 200, "{ref_out1}");
    let (status, ref_out2) = c.request("POST", "/v1/streams/s-1/prefill", &batch(11));
    assert_eq!(status, 200, "{ref_out2}");
    drop(c);
    reference.shutdown();

    // durable run: same prompt, then an abrupt stop before batch two
    let server = start(Some(&dir));
    let mut c = Client::connect(server.local_addr());
    let (status, body) = c.request("POST", "/v1/streams", "{}");
    assert_eq!(status, 201, "{body}");
    assert!(body.contains("\"stream\":\"s-1\""), "{body}");
    let (status, out1) = c.request("POST", "/v1/streams/s-1/prefill", &batch(1));
    assert_eq!(status, 200, "{out1}");
    assert_eq!(out1, ref_out1, "pre-crash fold diverged from the reference server");
    // a second stream whose open was acked but that never folded a row
    let (status, body) = c.request("POST", "/v1/streams", "{}");
    assert_eq!(status, 201, "{body}");
    assert!(body.contains("\"stream\":\"s-2\""), "{body}");
    drop(c);
    server.shutdown(); // abrupt: no drain, no final checkpoint — a crash

    // restart on the same data dir: both acked streams are recovered
    let server = start(Some(&dir));
    let mut c = Client::connect(server.local_addr());
    let (status, body) = c.request("GET", "/v1/streams/s-1", "");
    assert_eq!(status, 200, "{body}");
    assert!(body.contains("\"status\":\"active\""), "{body}");
    assert!(body.contains(&format!("\"tokens\":{ROWS}")), "{body}");
    let (status, body) = c.request("GET", "/v1/streams/s-2", "");
    assert_eq!(status, 200, "{body}");
    assert!(body.contains("\"tokens\":0"), "{body}");

    // the recovered stream folds batch two bit-identically
    let (status, out2) = c.request("POST", "/v1/streams/s-1/prefill", &batch(11));
    assert_eq!(status, 200, "{out2}");
    assert_eq!(out2, ref_out2, "recovered stream diverged from the never-died server");

    // a recovered wire id is never handed out twice
    let (status, body) = c.request("POST", "/v1/streams", "{}");
    assert_eq!(status, 201, "{body}");
    assert!(body.contains("\"stream\":\"s-3\""), "{body}");

    drop(c);
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn graceful_drain_writes_a_final_checkpoint_that_restart_recovers() {
    let dir = data_dir("drain");
    let server = start(Some(&dir));
    let mut c = Client::connect(server.local_addr());
    let (status, body) = c.request("POST", "/v1/streams", "{}");
    assert_eq!(status, 201, "{body}");
    let (status, body) = c.request("POST", "/v1/streams/s-1/prefill", &batch(5));
    assert_eq!(status, 200, "{body}");
    drop(c);
    server.drain();
    assert!(dir.join("checkpoint.macc").exists(), "drain must leave a final checkpoint");

    // the restarted server resumes from the checkpoint alone
    let server = start(Some(&dir));
    let mut c = Client::connect(server.local_addr());
    let (status, body) = c.request("GET", "/healthz", "");
    assert_eq!(status, 200, "{body}");
    assert!(body.contains("\"status\":\"ready\""), "{body}");
    let (status, body) = c.request("GET", "/v1/streams/s-1", "");
    assert_eq!(status, 200, "{body}");
    assert!(body.contains("\"status\":\"active\""), "{body}");
    assert!(body.contains(&format!("\"tokens\":{ROWS}")), "{body}");
    drop(c);
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_checkpoint_refuses_startup_with_a_typed_error() {
    let dir = data_dir("corrupt");
    let server = start(Some(&dir));
    let mut c = Client::connect(server.local_addr());
    let (status, body) = c.request("POST", "/v1/streams", "{}");
    assert_eq!(status, 201, "{body}");
    let (status, body) = c.request("POST", "/v1/streams/s-1/prefill", &batch(3));
    assert_eq!(status, 200, "{body}");
    drop(c);
    server.drain(); // leaves checkpoint.macc behind

    let path = dir.join("checkpoint.macc");
    let mut bytes = std::fs::read(&path).expect("checkpoint written");
    bytes[40] ^= 0x08;
    std::fs::write(&path, &bytes).expect("rewrite checkpoint");

    let durability = Some(DurabilityConfig::new(&dir));
    let serve = ServeConfig::new(8, DIMS);
    let err = Server::start(NetConfig::default(), spec(), serve, Default::default(), durability)
        .err()
        .expect("a corrupt checkpoint must refuse startup");
    assert!(err.to_string().contains("durable store"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}
