//! Integration tests for the HTTP/1.1 serving gateway (`serve::net`).
//!
//! Four layers of coverage:
//!
//! 1. The exhaustive `ServeError -> (HTTP status, code, Retry-After)`
//!    wire mapping, pinned variant by variant (no wildcard arm, so a
//!    new variant fails compilation here until its mapping is decided).
//! 2. Typed [`ServeConfig`] validation at construction.
//! 3. Adversarial raw-socket inputs — truncated, oversized, non-UTF8,
//!    depth-bombed, slow-loris — all answered with a 4xx within the
//!    read deadline, never a panic or a hang.
//! 4. End-to-end: N concurrent TCP clients (prefill + decode, chaos
//!    fault plan) whose surviving outputs must be bit-identical to the
//!    single-stream in-process decode, with backpressure rejects
//!    surfaced as `429` + `Retry-After` and zero 5xx.

use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use macformer::serve::net::http::HttpConfig;
use macformer::serve::net::{http_status, retry_after_ticks, run_socket};
use macformer::serve::{
    BackendSpec, EngineSpec, FaultPlan, LoadConfig, NetConfig, Router, RouterConfig, ServeConfig,
    ServeError, Server,
};

// ---------------------------------------------------------------------------
// satellite: the exhaustive ServeError wire mapping
// ---------------------------------------------------------------------------

/// Every [`ServeError`] variant's HTTP mapping in one table: status,
/// reason, machine code, and `Retry-After` ticks. The match below has
/// no wildcard, so a new variant cannot ship without a pinned mapping.
#[test]
fn serve_error_wire_mapping_is_exhaustive_and_stable() {
    let cases: Vec<(ServeError, u16, &str, &str, Option<u64>)> = vec![
        (
            ServeError::InvalidConfig { what: "dv must be > 0" },
            500,
            "Internal Server Error",
            "invalid_config",
            None,
        ),
        (ServeError::PoolFull { capacity: 8 }, 503, "Service Unavailable", "pool_full", Some(1)),
        (
            ServeError::Backpressure { max_pending: 4, retry_after_ticks: 3 },
            429,
            "Too Many Requests",
            "backpressure",
            Some(3),
        ),
        (
            // a zero hint still advertises a strictly positive wait
            ServeError::Backpressure { max_pending: 4, retry_after_ticks: 0 },
            429,
            "Too Many Requests",
            "backpressure",
            Some(1),
        ),
        (ServeError::UnknownStream, 404, "Not Found", "unknown_stream", None),
        (ServeError::StreamBusy, 409, "Conflict", "stream_busy", None),
        (ServeError::NoOutput, 409, "Conflict", "no_output", None),
        (
            ServeError::BadRow { what: "q", expected: 8, got: 3 },
            400,
            "Bad Request",
            "bad_row",
            None,
        ),
        (ServeError::NonFinite { what: "v" }, 422, "Unprocessable Entity", "non_finite", None),
        (ServeError::Expired, 410, "Gone", "expired", None),
        (ServeError::Faulted, 500, "Internal Server Error", "faulted", None),
        (
            ServeError::Session("backend refused".into()),
            500,
            "Internal Server Error",
            "session",
            None,
        ),
    ];
    for (err, status, reason, code, retry) in &cases {
        // exhaustiveness guard: every variant by name, no `_` arm
        match err {
            ServeError::InvalidConfig { .. } => {}
            ServeError::PoolFull { .. } => {}
            ServeError::Backpressure { .. } => {}
            ServeError::UnknownStream => {}
            ServeError::StreamBusy => {}
            ServeError::NoOutput => {}
            ServeError::BadRow { .. } => {}
            ServeError::NonFinite { .. } => {}
            ServeError::Expired => {}
            ServeError::Faulted => {}
            ServeError::Session(_) => {}
        }
        assert_eq!(http_status(err), (*status, *reason), "{code}");
        assert_eq!(err.code(), *code);
        assert_eq!(retry_after_ticks(err), *retry, "{code}");
        // a Retry-After only makes sense on statuses clients may retry
        if retry.is_some() {
            assert!(matches!(status, 429 | 503), "{code}: Retry-After on {status}");
        }
    }
}

// ---------------------------------------------------------------------------
// satellite: typed ServeConfig validation at construction
// ---------------------------------------------------------------------------

#[test]
fn serve_config_validation_rejects_degenerate_configs_with_typed_errors() {
    assert_eq!(
        ServeConfig::new(0, 4).validate(),
        Err(ServeError::InvalidConfig { what: "max_streams must be > 0" })
    );
    assert_eq!(
        ServeConfig::new(4, 0).validate(),
        Err(ServeError::InvalidConfig { what: "dv must be > 0" })
    );
    assert_eq!(ServeConfig::new(1, 1).validate(), Ok(()));

    // the gateway refuses to bind at all on an invalid config
    let cfg = small_cfg();
    let spec = spec_for(&cfg);
    let bad = ServeConfig { max_streams: 0, ..ServeConfig::new(1, cfg.dv) };
    let err = Server::start(NetConfig::default(), spec, bad, cfg.resilience.clone(), None)
        .err()
        .expect("zero-capacity config must not start a server");
    assert_eq!(err.to_string(), "invalid serve config: max_streams must be > 0");
}

// ---------------------------------------------------------------------------
// shared fixtures
// ---------------------------------------------------------------------------

/// A small, fast engine shape shared by the gateway tests.
fn small_cfg() -> LoadConfig {
    LoadConfig {
        streams: 4,
        tokens: 12,
        prompt: 4,
        head_dim: 8,
        dv: 8,
        num_features: 16,
        min_batch: 2,
        ..LoadConfig::default()
    }
}

fn spec_for(cfg: &LoadConfig) -> EngineSpec {
    EngineSpec {
        kernel: cfg.kernel,
        backend: cfg.backend,
        head_dim: cfg.head_dim,
        dv: cfg.dv,
        num_features: cfg.num_features,
        seed: cfg.seed,
    }
}

fn server_for(cfg: &LoadConfig, net: NetConfig) -> Server {
    let serve = ServeConfig { min_batch: cfg.min_batch, ..ServeConfig::new(cfg.streams, cfg.dv) };
    Server::start(net, spec_for(cfg), serve, cfg.resilience.clone(), None).expect("server start")
}

struct RawResponse {
    status: u16,
    /// Lower-cased head (status line + headers).
    head: String,
    body: String,
}

/// One raw request on a fresh connection, read to connection close.
/// `half_close` shuts the write side after sending, which a keep-alive
/// server treats as a clean end-of-session once it has answered.
fn one_shot(addr: SocketAddr, payload: &[u8], half_close: bool) -> RawResponse {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(10))).expect("read timeout");
    stream.write_all(payload).expect("send request");
    if half_close {
        let _ = stream.shutdown(Shutdown::Write);
    }
    let mut buf = Vec::new();
    // tolerate a reset after the response has been received in full
    let _ = stream.read_to_end(&mut buf);
    let text = String::from_utf8_lossy(&buf).into_owned();
    let split = text.find("\r\n\r\n").unwrap_or_else(|| panic!("no response head in {text:?}"));
    let head = text[..split].to_ascii_lowercase();
    let status = head
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("bad status line in {head:?}"));
    RawResponse { status, head, body: text[split + 4..].to_string() }
}

/// A keep-alive client for hammering one connection with many GETs.
struct RawClient {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl RawClient {
    fn connect(addr: SocketAddr) -> RawClient {
        let stream = TcpStream::connect(addr).expect("connect");
        stream.set_read_timeout(Some(Duration::from_secs(10))).expect("read timeout");
        let _ = stream.set_nodelay(true);
        RawClient { stream, buf: Vec::new() }
    }

    fn get(&mut self, path: &str) -> (u16, String, String) {
        self.request("GET", path, "")
    }

    /// One request on the persistent connection: (status, lowercased
    /// head, body), leaving the connection open for the next request.
    fn request(&mut self, method: &str, path: &str, body: &str) -> (u16, String, String) {
        let req = format!(
            "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        self.stream.write_all(req.as_bytes()).expect("send request");
        let head_end = loop {
            if let Some(pos) = self.buf.windows(4).position(|w| w == b"\r\n\r\n") {
                break pos + 4;
            }
            self.read_more("head");
        };
        let head = String::from_utf8_lossy(&self.buf[..head_end]).to_ascii_lowercase();
        let status: u16 = head
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .unwrap_or_else(|| panic!("bad status line in {head:?}"));
        let len: usize = head
            .lines()
            .find_map(|l| l.strip_prefix("content-length:"))
            .map(|v| v.trim().parse().expect("content-length"))
            .unwrap_or(0);
        while self.buf.len() < head_end + len {
            self.read_more("body");
        }
        let body = String::from_utf8_lossy(&self.buf[head_end..head_end + len]).into_owned();
        self.buf.drain(..head_end + len);
        (status, head, body)
    }

    fn read_more(&mut self, what: &str) {
        let mut chunk = [0u8; 4096];
        let n = self.stream.read(&mut chunk).expect("read response");
        assert!(n > 0, "server closed mid-{what}");
        self.buf.extend_from_slice(&chunk[..n]);
    }
}

// ---------------------------------------------------------------------------
// routing + typed errors over a real socket
// ---------------------------------------------------------------------------

#[test]
fn gateway_serves_health_spec_and_typed_errors() {
    let cfg = small_cfg();
    let server = server_for(&cfg, NetConfig::default());
    let addr = server.local_addr();

    let health = one_shot(addr, b"GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n", true);
    assert_eq!(health.status, 200);
    assert!(health.body.contains("\"status\":\"ready\""), "{}", health.body);
    assert!(health.body.contains("\"tick_no\""), "{}", health.body);

    let spec = one_shot(addr, b"GET /v1/spec HTTP/1.1\r\nHost: t\r\n\r\n", true);
    assert_eq!(spec.status, 200);
    assert!(spec.body.contains("\"kernel\":\"exp\""), "{}", spec.body);
    assert!(spec.body.contains("\"backend\":\"host\""), "{}", spec.body);
    assert!(spec.body.contains("\"head_dim\":8"), "{}", spec.body);

    let missing = one_shot(addr, b"GET /v1/nope HTTP/1.1\r\nHost: t\r\n\r\n", true);
    assert_eq!(missing.status, 404);
    assert!(missing.body.contains("\"error\":\"not_found\""), "{}", missing.body);

    // a typed ServeError crossing the wire: decode on a never-opened
    // stream maps to 404 unknown_stream (mapping pinned above)
    let body = r#"{"q":[1,0,0,0,0,0,0,0],"k":[1,0,0,0,0,0,0,0],"v":[1,0,0,0,0,0,0,0]}"#;
    let req = format!(
        "POST /v1/streams/s-999/decode HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    let unknown = one_shot(addr, req.as_bytes(), true);
    assert_eq!(unknown.status, 404);
    assert!(unknown.body.contains("\"error\":\"unknown_stream\""), "{}", unknown.body);
    assert!(unknown.body.contains("\"retryable\":false"), "{}", unknown.body);

    let gone = one_shot(addr, b"DELETE /v1/streams/s-999 HTTP/1.1\r\nHost: t\r\n\r\n", true);
    assert_eq!(gone.status, 404);
    server.shutdown();
}

// ---------------------------------------------------------------------------
// satellite: adversarial wire inputs
// ---------------------------------------------------------------------------

#[test]
fn adversarial_wire_inputs_answer_4xx_without_panic_or_hang() {
    let cfg = small_cfg();
    let http = HttpConfig {
        max_head: 1024,
        max_body: 64 * 1024,
        read_timeout: Duration::from_millis(400),
    };
    let server = server_for(&cfg, NetConfig { http, ..NetConfig::default() });
    let addr = server.local_addr();

    // garbage request line
    let r = one_shot(addr, b"GARBAGE\r\n\r\n", true);
    assert_eq!(r.status, 400);
    assert!(r.body.contains("\"error\":\"bad_request\""), "{}", r.body);

    // non-UTF8 bytes in the head
    let r = one_shot(addr, b"GET /healthz\xff HTTP/1.1\r\nHost: t\r\n\r\n", true);
    assert_eq!(r.status, 400);

    // peer gives up mid-Content-Length: truncated body
    let r = one_shot(addr, b"POST /v1/streams HTTP/1.1\r\nContent-Length: 50\r\n\r\n{}", true);
    assert_eq!(r.status, 400);
    assert!(r.body.contains("truncated"), "{}", r.body);

    // oversized declared Content-Length is refused before any body read
    let r = one_shot(addr, b"POST /v1/streams HTTP/1.1\r\nContent-Length: 10000000\r\n\r\n", true);
    assert_eq!(r.status, 413);
    assert!(r.body.contains("\"error\":\"body_too_large\""), "{}", r.body);

    // a body-bearing method must declare Content-Length
    let r = one_shot(addr, b"POST /v1/streams HTTP/1.1\r\nHost: t\r\n\r\n", true);
    assert_eq!(r.status, 411);

    // head past max_head, even when it arrives complete in one read
    let huge = format!("GET /healthz HTTP/1.1\r\nX-Pad: {}\r\n\r\n", "a".repeat(1100));
    let r = one_shot(addr, huge.as_bytes(), true);
    assert_eq!(r.status, 431);

    // depth-bombed JSON: the borrowing scanner is depth-capped and
    // iterative, so 40k open brackets cannot overflow the stack
    let mut nested = String::from("{\"q\":");
    nested.push_str(&"[".repeat(40_000));
    let req = format!(
        "POST /v1/streams/s-1/decode HTTP/1.1\r\nContent-Length: {}\r\n\r\n{nested}",
        nested.len()
    );
    let r = one_shot(addr, req.as_bytes(), true);
    assert_eq!(r.status, 400);
    assert!(r.body.contains("\"error\":\"bad_body\""), "{}", r.body);

    // the JSON grammar cannot spell NaN; it dies in parse, not the fold
    let body = r#"{"q":[NaN],"k":[],"v":[]}"#;
    let req = format!(
        "POST /v1/streams/s-1/decode HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    let r = one_shot(addr, req.as_bytes(), true);
    assert_eq!(r.status, 400);
    assert!(r.body.contains("\"error\":\"bad_body\""), "{}", r.body);

    // slow loris: a partial head then silence is cut off by the read
    // deadline, not held open
    let started = Instant::now();
    let r = one_shot(addr, b"POST /v1/streams HTTP/1.1\r\nHost: t", false);
    assert_eq!(r.status, 408);
    assert!(
        started.elapsed() < Duration::from_secs(5),
        "slow-loris connection held past the read deadline"
    );

    // after all that abuse the gateway still answers cleanly
    let r = one_shot(addr, b"GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n", true);
    assert_eq!(r.status, 200);
    server.shutdown();
}

// ---------------------------------------------------------------------------
// backpressure over the wire: 429 + Retry-After
// ---------------------------------------------------------------------------

/// With a depth-1 ingress queue and eight connections hammering it,
/// some requests must be bounced with `429` + `Retry-After` (never a
/// hang, never a 5xx), and the gateway recovers to clean service.
#[test]
fn ingress_backpressure_surfaces_as_429_with_retry_after() {
    let cfg = small_cfg();
    let net = NetConfig { queue_depth: 1, workers: 10, ..NetConfig::default() };
    let server = server_for(&cfg, net);
    let addr = server.local_addr();

    let got_429 = AtomicU64::new(0);
    let unexpected = AtomicU64::new(0);
    std::thread::scope(|scope| {
        // park the engine in long synchronous prefills: while one runs,
        // the depth-1 ingress queue holds at most one waiting command
        // and every further healthz below must bounce with a 429
        scope.spawn(|| {
            let mut client = RawClient::connect(addr);
            let mut row = "0.5,".repeat(2048 * 8);
            row.pop(); // drop the trailing comma
            let body = format!("{{\"q\":[{row}],\"k\":[{row}],\"v\":[{row}]}}");
            for _ in 0..4 {
                let (status, _, resp) = client.request("POST", "/v1/streams", "{}");
                if status != 201 {
                    continue; // bounced by our own flood; try the next slot
                }
                let sid = resp.split('"').nth(3).unwrap_or("s-1").to_string();
                for _ in 0..50 {
                    let path = format!("/v1/streams/{sid}/prefill");
                    if client.request("POST", &path, &body).0 != 429 {
                        break;
                    }
                }
            }
        });
        for _ in 0..8 {
            scope.spawn(|| {
                let mut client = RawClient::connect(addr);
                for _ in 0..2000 {
                    let (status, head, body) = client.get("/healthz");
                    match status {
                        200 => assert!(body.contains("\"status\":\"ready\""), "{body}"),
                        429 => {
                            assert!(head.contains("retry-after: 1"), "429 without Retry-After");
                            assert!(body.contains("\"error\":\"ingress_full\""), "{body}");
                            assert!(body.contains("\"retryable\":true"), "{body}");
                            got_429.fetch_add(1, Ordering::Relaxed);
                        }
                        _ => {
                            unexpected.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    if got_429.load(Ordering::Relaxed) >= 4 {
                        break;
                    }
                }
            });
        }
    });
    assert_eq!(unexpected.load(Ordering::Relaxed), 0, "non-200/429 answer under flood");
    assert!(
        got_429.load(Ordering::Relaxed) >= 1,
        "no 429 from an 8-way flood of a depth-1 ingress queue"
    );

    // the queue drains and service is clean again
    let mut client = RawClient::connect(addr);
    let ok = (0..50).any(|_| client.get("/healthz").0 == 200);
    assert!(ok, "gateway did not recover after the flood");
    server.shutdown();
}

// ---------------------------------------------------------------------------
// end-to-end: concurrent chaos clients, bit-identical survivors
// ---------------------------------------------------------------------------

/// A clean multi-client run over real sockets: every stream prefills a
/// prompt, decodes to completion, and the gateway's outputs verify
/// bit-identical against the single-stream in-process decode.
#[test]
fn concurrent_socket_decode_is_bit_identical_to_in_process() {
    let cfg = small_cfg();
    let net = NetConfig { workers: cfg.streams, ..NetConfig::default() };
    let server = server_for(&cfg, net);
    let addr = server.local_addr().to_string();
    let report = run_socket(&cfg, &addr).expect("socket load run");
    server.shutdown();
    assert_eq!(report.verified, Some(true), "socket outputs diverged from in-process decode");
    assert_eq!(report.stream_errors, 0);
    assert_eq!(report.http_5xx, 0);
    assert_eq!(report.faulted_streams, 0);
    assert_eq!(report.poisoned_streams, 0);
    assert_eq!(report.tokens_total, (cfg.streams * cfg.tokens) as u64);
}

/// The acceptance run: six concurrent TCP clients under a chaos plan
/// (two planned fold panics, forced hibernations mid-decode). The
/// survivors — and every casualty's surviving prefix — must be
/// bit-identical to the in-process single-stream decode, the planned
/// faults must land as in-stream typed error frames (never a 5xx),
/// and no fault may leak into a neighbour stream.
#[test]
fn concurrent_chaos_clients_verify_bit_identical_with_zero_5xx() {
    let cfg = LoadConfig {
        streams: 6,
        tokens: 24,
        prompt: 5,
        faults: FaultPlan { seed: 11, panics: 2, hibernate_every: 3, ..FaultPlan::none() },
        ..small_cfg()
    };
    let net = NetConfig { workers: cfg.streams, queue_depth: 64, ..NetConfig::default() };
    let server = server_for(&cfg, net);
    let addr = server.local_addr().to_string();
    let report = run_socket(&cfg, &addr).expect("socket chaos run");
    server.shutdown();
    assert_eq!(report.verified, Some(true), "survivors diverged from in-process decode");
    assert_eq!(report.stream_errors, 0, "unexpected stream errors under chaos");
    assert_eq!(report.http_5xx, 0, "chaos must surface as typed frames, not 5xx");
    assert_eq!(report.faulted_streams, 2, "exactly the planned fold panics land");
    assert_eq!(report.poisoned_streams, 0, "a fault leaked into a neighbour stream");
    assert!(report.tokens_total > 0);
}

// ---------------------------------------------------------------------------
// graceful drain: refuse new opens, keep serving admitted streams
// ---------------------------------------------------------------------------

/// [`Server::begin_drain`] flips the gateway without stopping it: new
/// opens bounce with a retryable `503 draining` + `Retry-After`,
/// `healthz` reports draining, and a stream admitted before the drain
/// still prefills, answers its resume probe, and closes cleanly.
#[test]
fn draining_gateway_refuses_new_opens_but_finishes_admitted_work() {
    let cfg = small_cfg();
    let server = server_for(&cfg, NetConfig::default());
    let addr = server.local_addr();

    let mut client = RawClient::connect(addr);
    let (status, _, resp) = client.request("POST", "/v1/streams", "{}");
    assert_eq!(status, 201, "{resp}");
    let sid = resp.split('"').nth(3).expect("stream id").to_string();

    server.begin_drain();

    let (status, _, body) = client.get("/healthz");
    assert_eq!(status, 503);
    assert!(body.contains("\"status\":\"draining\""), "{body}");

    let (status, head, body) = client.request("POST", "/v1/streams", "{}");
    assert_eq!(status, 503);
    assert!(head.contains("retry-after: 1"), "draining 503 without Retry-After: {head}");
    assert!(body.contains("\"error\":\"draining\""), "{body}");
    assert!(body.contains("\"retryable\":true"), "{body}");

    // the admitted stream is still served mid-drain: prefill one row...
    let row = "[1,0,0,0,0,0,0,0]";
    let body = format!("{{\"q\":{row},\"k\":{row},\"v\":{row}}}");
    let (status, _, resp) = client.request("POST", &format!("/v1/streams/{sid}/prefill"), &body);
    assert_eq!(status, 200, "{resp}");

    // ...the resume probe sees it...
    let (status, _, resp) = client.get(&format!("/v1/streams/{sid}"));
    assert_eq!(status, 200, "{resp}");
    assert!(resp.contains("\"status\":\"active\""), "{resp}");
    assert!(resp.contains("\"tokens\":1"), "{resp}");

    // ...and the close lands before the gateway winds down
    let (status, _, _) = client.request("DELETE", &format!("/v1/streams/{sid}"), "");
    assert_eq!(status, 200);
    drop(client);
    server.drain();
}

// ---------------------------------------------------------------------------
// satellite: the router passes the backend wire contract through verbatim
// ---------------------------------------------------------------------------

/// A router fronting one in-process gateway, with a short proxy retry
/// budget (so retryable-passthrough tests don't sit out the default)
/// and a high fail threshold (so a deliberately-draining backend is
/// not probed into `down` mid-test).
fn router_over(backend: &Server, workers: usize) -> Router {
    Router::start(RouterConfig {
        workers,
        retry_budget: Duration::from_millis(50),
        fail_threshold: 10_000,
        backends: vec![BackendSpec { addr: backend.local_addr().to_string(), data_dir: None }],
        ..RouterConfig::default()
    })
    .expect("router start")
}

/// The full socket load run — opens, prefills, SSE decodes, deletes,
/// bit-exact verification against the in-process decode — driven
/// through the router instead of straight at the gateway. The proxy
/// hop must be invisible: same outputs, zero 5xx, zero errors.
#[test]
fn routed_socket_decode_is_bit_identical_to_in_process() {
    let cfg = small_cfg();
    let net = NetConfig { workers: cfg.streams + 8, ..NetConfig::default() };
    let server = server_for(&cfg, net);
    let router = router_over(&server, cfg.streams + 2);
    let addr = router.local_addr().to_string();
    let report = run_socket(&cfg, &addr).expect("routed socket load run");
    router.shutdown();
    server.shutdown();
    assert_eq!(report.verified, Some(true), "routed outputs diverged from in-process decode");
    assert_eq!(report.stream_errors, 0);
    assert_eq!(report.http_5xx, 0);
    assert_eq!(report.poisoned_streams, 0);
    assert_eq!(report.tokens_total, (cfg.streams * cfg.tokens) as u64);
}

/// Every wire-triggerable [`ServeError`] answer must cross the proxy
/// hop unmodified: same status, same `Retry-After`, and — for
/// backend-origin errors — the same body bytes. The router may retry
/// a retryable 503 within its budget, but once the budget is spent the
/// backend's verdict passes through verbatim, not rewritten.
#[test]
fn router_passes_backend_error_contract_through_verbatim() {
    let cfg = small_cfg();
    // a one-slot pool makes pool_full reachable with a single open
    let serve = ServeConfig { min_batch: cfg.min_batch, ..ServeConfig::new(1, cfg.dv) };
    let server =
        Server::start(NetConfig::default(), spec_for(&cfg), serve, cfg.resilience.clone(), None)
            .expect("server start");
    let router = router_over(&server, 2);

    let mut direct = RawClient::connect(server.local_addr());
    let mut routed = RawClient::connect(router.local_addr());

    // the open that takes the only slot goes through the router, so
    // the router owns a live mapping for the bad_row probes below
    let (status, head, resp) = routed.request("POST", "/v1/streams", "{}");
    assert_eq!(status, 201, "{resp}");
    let rid = resp.split('"').nth(3).expect("public stream id").to_string();
    assert!(rid.starts_with("r-"), "router must mint public ids, got {rid}");
    assert!(
        head.contains(&format!("x-macformer-node: {}", router.node_id())),
        "router-origin answer must carry the router's node id: {head}"
    );

    // pool_full: retryable 503 + Retry-After — after the router's
    // retry budget is spent, byte-identical to the direct answer
    let (d_status, d_head, d_body) = direct.request("POST", "/v1/streams", "{}");
    let (r_status, r_head, r_body) = routed.request("POST", "/v1/streams", "{}");
    assert_eq!((d_status, r_status), (503, 503));
    for head in [&d_head, &r_head] {
        assert!(head.contains("retry-after: 1"), "pool_full without Retry-After: {head}");
    }
    assert_eq!(d_body, r_body, "pool_full body rewritten by the proxy hop");
    assert!(r_body.contains("\"error\":\"pool_full\""), "{r_body}");
    assert!(r_body.contains("\"retryable\":true"), "{r_body}");

    // bad_row: a non-retryable 400 passes through with the body intact
    let bad = r#"{"q":[1,0,0],"k":[1,0,0,0,0,0,0,0],"v":[1,0,0,0,0,0,0,0]}"#;
    let sid = {
        // the backend id behind the router's only mapping
        let map = router.stream_map();
        assert_eq!(map.len(), 1);
        format!("s-{}", 0)
    };
    let (d_status, _, d_body) = direct.request("POST", &format!("/v1/streams/{sid}/decode"), bad);
    let (r_status, r_head, r_body) =
        routed.request("POST", &format!("/v1/streams/{rid}/decode"), bad);
    assert_eq!((d_status, r_status), (400, 400));
    assert_eq!(d_body, r_body, "bad_row body rewritten by the proxy hop");
    assert!(r_body.contains("\"error\":\"bad_row\""), "{r_body}");
    assert!(r_body.contains("\"retryable\":false"), "{r_body}");
    assert!(!r_head.contains("retry-after"), "Retry-After invented on a 400: {r_head}");

    // unknown_stream: the router answers unmapped public ids itself,
    // with the same code/status the backend pins for unknown backend
    // ids — the contract is one vocabulary, whoever speaks it
    let ok = r#"{"q":[1,0,0,0,0,0,0,0],"k":[1,0,0,0,0,0,0,0],"v":[1,0,0,0,0,0,0,0]}"#;
    let (d_status, _, d_body) = direct.request("POST", "/v1/streams/s-999/decode", ok);
    let (r_status, _, r_body) = routed.request("POST", "/v1/streams/r-999/decode", ok);
    assert_eq!((d_status, r_status), (404, 404));
    for body in [&d_body, &r_body] {
        assert!(body.contains("\"error\":\"unknown_stream\""), "{body}");
        assert!(body.contains("\"retryable\":false"), "{body}");
    }

    // draining: flip the backend into drain; its retryable refusal
    // crosses the hop verbatim once the router's budget is spent
    server.begin_drain();
    let (d_status, d_head, d_body) = direct.request("POST", "/v1/streams", "{}");
    let (r_status, r_head, r_body) = routed.request("POST", "/v1/streams", "{}");
    assert_eq!((d_status, r_status), (503, 503));
    for head in [&d_head, &r_head] {
        assert!(head.contains("retry-after: 1"), "draining without Retry-After: {head}");
    }
    assert_eq!(d_body, r_body, "draining body rewritten by the proxy hop");
    assert!(r_body.contains("\"error\":\"draining\""), "{r_body}");

    drop(direct);
    drop(routed);
    router.shutdown();
    server.shutdown();
}

/// Router-origin surfaces: `/healthz` says `router` and names the
/// fleet, `/metrics` exposes the router families, unknown paths 404
/// with the shared vocabulary, and deleting a mapped stream through
/// the router unmaps it (a second delete is an honest 404).
#[test]
fn router_health_metrics_and_stream_lifecycle() {
    let cfg = small_cfg();
    let net = NetConfig { workers: 6, ..NetConfig::default() };
    let server = server_for(&cfg, net);
    let backend_addr = server.local_addr().to_string();
    let router = router_over(&server, 2);
    let mut client = RawClient::connect(router.local_addr());

    let (status, head, body) = client.get("/healthz");
    assert_eq!(status, 200, "{body}");
    assert!(body.contains("\"role\":\"router\""), "{body}");
    assert!(body.contains(&backend_addr), "fleet missing from {body}");
    assert!(
        head.contains(&format!("x-macformer-node: {}", router.node_id())),
        "router /healthz must carry the router's node id: {head}"
    );

    let (status, _, body) = client.get("/metrics");
    assert_eq!(status, 200);
    for family in [
        "macformer_router_backend_health",
        "macformer_router_streams",
        "macformer_router_migrations_total",
    ] {
        assert!(body.contains(family), "{family} missing from /metrics:\n{body}");
    }

    let (status, _, body) = client.get("/v1/nope");
    assert_eq!(status, 404);
    assert!(body.contains("\"error\":\"not_found\""), "{body}");

    // spec is proxied from the backend
    let (status, _, body) = client.get("/v1/spec");
    assert_eq!(status, 200);
    assert!(body.contains("\"kernel\":\"exp\""), "{body}");

    // open → delete → the mapping is gone, not leaked
    let (status, _, resp) = client.request("POST", "/v1/streams", "{}");
    assert_eq!(status, 201, "{resp}");
    let rid = resp.split('"').nth(3).expect("public stream id").to_string();
    assert_eq!(router.stream_map().len(), 1);
    let (status, _, _) = client.request("DELETE", &format!("/v1/streams/{rid}"), "");
    assert_eq!(status, 200);
    assert_eq!(router.stream_map().len(), 0, "delete must unmap the public id");
    let (status, _, body) = client.request("DELETE", &format!("/v1/streams/{rid}"), "");
    assert_eq!(status, 404, "{body}");

    drop(client);
    router.shutdown();
    server.shutdown();
}
