//! Shared helpers for the PJRT integration tests (the `common/mod.rs`
//! layout keeps this out of the test-binary list).
#![allow(dead_code)] // each test binary uses a subset of these helpers

use std::path::PathBuf;

use macformer::runtime::{client, Registry};

/// True iff a PJRT runtime is actually available. The offline `xla`
/// stub (which can never initialize) is a *skip*; a real backend
/// failing to initialize is a regression and panics — skipping would
/// turn it into a silent green run.
pub fn pjrt_or_skip() -> bool {
    match client::handle() {
        Ok(_) => true,
        Err(e) => {
            let msg = format!("{e}");
            assert!(
                msg.contains("offline xla stub"),
                "PJRT client failed on a non-stub build (regression, not a skip): {msg}"
            );
            eprintln!("skipping: {msg}");
            false
        }
    }
}

/// `None` => prerequisites genuinely absent (stub backend, or no
/// artifacts directory was ever built). Artifacts that exist but fail
/// to parse are a regression and panic instead of skipping.
pub fn registry_or_skip() -> Option<Registry> {
    if !pjrt_or_skip() {
        return None;
    }
    let dir = PathBuf::from(
        std::env::var("MACFORMER_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts at {dir:?} (run `make artifacts`)");
        return None;
    }
    Some(Registry::open(&dir).expect("artifacts present but unreadable — regression, not a skip"))
}
