//! Serve-vs-single-stream equivalence with the SIMD dispatch pinned to
//! each arm. `set_active`/`reset` are process-global, so this file owns
//! a whole test binary with one test (the `simd_dispatch.rs`
//! convention) — flipping arms here cannot race with any other test's
//! dispatch reads.
//!
//! On both arms the micro-batched serve path and the lone
//! single-stream decode run the *same* phi kernels and the *same* fold
//! code on identical inputs, so the outputs must be bit-identical —
//! not merely close — scalar and AVX2+FMA alike.

use macformer::fastpath::simd;
use macformer::serve::loadgen::{run, Arrival, LoadConfig};

#[test]
fn serve_is_bit_identical_to_single_stream_on_both_arms() {
    let cfg = LoadConfig {
        streams: 16,
        tokens: 10,
        // chunked prompt prefill at admission: decode after the prompt
        // must STILL be bit-identical on both arms (the prefilled
        // state is bit-compatible with the fold per arm)
        prompt: 9,
        head_dim: 6,
        dv: 5,
        num_features: 24,
        arrival: Arrival::Bursty,
        seed: 0xA4A5,
        ..LoadConfig::default()
    };
    // scalar arm: always available
    assert!(!simd::set_active(false));
    let scalar = run(&cfg).unwrap();
    assert_eq!(scalar.stream_errors, 0);
    assert_eq!(
        scalar.verified,
        Some(true),
        "scalar arm: serve diverged from single-stream (max |diff| {})",
        scalar.max_abs_diff
    );
    // vector arm, where the host supports it
    let vector_on = simd::set_active(true);
    assert_eq!(vector_on, simd::supported());
    if vector_on {
        let vector = run(&cfg).unwrap();
        assert_eq!(vector.stream_errors, 0);
        assert_eq!(
            vector.verified,
            Some(true),
            "vector arm: serve diverged from single-stream (max |diff| {})",
            vector.max_abs_diff
        );
    }
    simd::reset();
}
