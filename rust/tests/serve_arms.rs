//! Serve-vs-single-stream equivalence with the SIMD dispatch pinned to
//! each arm. `set_active`/`reset` are process-global, so this file owns
//! a whole test binary with one test (the `simd_dispatch.rs`
//! convention) — flipping arms here cannot race with any other test's
//! dispatch reads.
//!
//! On both arms the micro-batched serve path and the lone
//! single-stream decode run the *same* phi kernels and the *same* fold
//! code on identical inputs, so the outputs must be bit-identical —
//! not merely close — scalar and AVX2+FMA alike.

use macformer::fastpath::simd;
use macformer::serve::loadgen::{run, Arrival, LoadConfig};
use macformer::serve::{FaultPlan, ResilienceConfig};

/// Chaos variant of the arms check: a fixed fault plan (NaN tokens,
/// one planned panic casualty, forced hibernate/restore cycles,
/// stalled clients) plus an aggressive idle-hibernate deadline. On
/// each arm, every surviving output prefix must still be bit-identical
/// to that arm's own single-stream decode — i.e. hibernation snapshots
/// round-trip bit-exactly under both the scalar and AVX2+FMA folds —
/// and the planned casualty count is arm-independent because the fault
/// plan is a pure function of (seed, stream, token).
fn chaos_cfg() -> LoadConfig {
    LoadConfig {
        streams: 8,
        tokens: 8,
        head_dim: 6,
        dv: 5,
        num_features: 24,
        arrival: Arrival::Closed,
        seed: 0xC4A0,
        faults: FaultPlan {
            seed: 77,
            nan_every: 3,
            panics: 1,
            hibernate_every: 2,
            delay_every: 5,
            delay_ticks: 2,
        },
        resilience: ResilienceConfig {
            idle_hibernate_ticks: 2,
            ..ResilienceConfig::default()
        },
        ..LoadConfig::default()
    }
}

fn run_chaos(arm: &str) {
    let report = run(&chaos_cfg()).unwrap();
    assert_eq!(report.stream_errors, 0, "{arm} arm");
    assert_eq!(report.faulted_streams, 1, "{arm} arm: exactly the planned casualty");
    assert_eq!(report.poisoned_streams, 0, "{arm} arm: no poison escaped");
    assert_eq!(
        report.verified,
        Some(true),
        "{arm} arm: chaos survivors diverged (max |diff| {})",
        report.max_abs_diff
    );
    assert!(report.telemetry.hibernations() > 0, "{arm} arm");
    assert!(report.telemetry.restores() > 0, "{arm} arm");
}

#[test]
fn serve_is_bit_identical_to_single_stream_on_both_arms() {
    let cfg = LoadConfig {
        streams: 16,
        tokens: 10,
        // chunked prompt prefill at admission: decode after the prompt
        // must STILL be bit-identical on both arms (the prefilled
        // state is bit-compatible with the fold per arm)
        prompt: 9,
        head_dim: 6,
        dv: 5,
        num_features: 24,
        arrival: Arrival::Bursty,
        seed: 0xA4A5,
        ..LoadConfig::default()
    };
    // scalar arm: always available
    assert!(!simd::set_active(false));
    run_chaos("scalar");
    let scalar = run(&cfg).unwrap();
    assert_eq!(scalar.stream_errors, 0);
    assert_eq!(
        scalar.verified,
        Some(true),
        "scalar arm: serve diverged from single-stream (max |diff| {})",
        scalar.max_abs_diff
    );
    // vector arm, where the host supports it
    let vector_on = simd::set_active(true);
    assert_eq!(vector_on, simd::supported());
    if vector_on {
        run_chaos("vector");
        let vector = run(&cfg).unwrap();
        assert_eq!(vector.stream_errors, 0);
        assert_eq!(
            vector.verified,
            Some(true),
            "vector arm: serve diverged from single-stream (max |diff| {})",
            vector.max_abs_diff
        );
    }
    simd::reset();
}
