//! Property tests on the paper's invariants, via util::proptest (no PJRT
//! — pure host math, safe to run multi-threaded).

use macformer::attn::Kernel;
use macformer::data::batcher::Batcher;
use macformer::metrics::bleu::corpus_bleu;
use macformer::reference::{attention, rmf};
use macformer::tensor::Tensor;
use macformer::util::proptest::{check, PropResult};
use macformer::util::rng::Rng;

fn randn(rng: &mut Rng, shape: &[usize], scale: f32) -> Tensor {
    let mut t = Tensor::zeros(shape);
    for x in t.data.iter_mut() {
        *x = rng.normal() * scale;
    }
    t
}

/// Softmax attention rows are convex combinations: outputs stay inside
/// the per-column [min, max] envelope of V.
#[test]
fn prop_softmax_attention_is_convex_combination() {
    check(
        30,
        |rng| {
            let n = rng.range(2, 12);
            let d = rng.range(2, 8);
            let q = randn(rng, &[n, d], 1.0);
            let k = randn(rng, &[n, d], 1.0);
            let v = randn(rng, &[n, 3], 2.0);
            vec![
                q.data,
                k.data,
                v.data,
                vec![n as f32, d as f32],
            ]
        },
        |input: &Vec<Vec<f32>>| -> PropResult {
            let n = input[3][0] as usize;
            let d = input[3][1] as usize;
            let q = Tensor::from_vec(&[n, d], input[0].clone());
            let k = Tensor::from_vec(&[n, d], input[1].clone());
            let v = Tensor::from_vec(&[n, 3], input[2].clone());
            let out = attention::softmax_attention(&q, &k, &v, false);
            for c in 0..3 {
                let col: Vec<f32> = (0..n).map(|i| v.data[i * 3 + c]).collect();
                let (lo, hi) = col
                    .iter()
                    .fold((f32::INFINITY, f32::NEG_INFINITY), |(l, h), x| {
                        (l.min(*x), h.max(*x))
                    });
                for i in 0..n {
                    let o = out.data[i * 3 + c];
                    if o < lo - 1e-4 || o > hi + 1e-4 {
                        return Err(format!("out[{i},{c}]={o} outside [{lo},{hi}]"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Kernelized attention with the exp kernel equals softmax attention
/// (Definition 2 reduces to Definition 1) for any well-scaled inputs.
#[test]
fn prop_exp_kernelized_equals_softmax() {
    check(
        30,
        |rng| {
            let n = rng.range(2, 10);
            let d = rng.range(2, 6);
            let q = randn(rng, &[n, d], 0.6);
            let k = randn(rng, &[n, d], 0.6);
            let v = randn(rng, &[n, 2], 1.0);
            vec![q.data, k.data, v.data, vec![n as f32, d as f32]]
        },
        |input: &Vec<Vec<f32>>| -> PropResult {
            let n = input[3][0] as usize;
            let d = input[3][1] as usize;
            let q = Tensor::from_vec(&[n, d], input[0].clone());
            let k = Tensor::from_vec(&[n, d], input[1].clone());
            let v = Tensor::from_vec(&[n, 2], input[2].clone());
            let a = attention::softmax_attention(&q, &k, &v, false);
            let b = attention::kernelized_attention(Kernel::Exp, &q, &k, &v, false, 0.0);
            let diff = a.max_abs_diff(&b);
            if diff > 2e-3 {
                return Err(format!("max diff {diff}"));
            }
            Ok(())
        },
    );
}

/// The factored linear contraction equals explicit kernel-score attention
/// when phi comes from an actual RMF map (any Table-1 kernel).
#[test]
fn prop_linear_contraction_matches_explicit_scores() {
    check(
        20,
        |rng| {
            let kernel_idx = rng.below(5);
            let n = rng.range(3, 10);
            let seed = rng.next_u64() as f32;
            vec![vec![kernel_idx as f32, n as f32, seed]]
        },
        |input: &Vec<Vec<f32>>| -> PropResult {
            let kernel = Kernel::MACLAURIN[input[0][0] as usize];
            let n = input[0][1] as usize;
            let mut rng = Rng::new(input[0][2] as u64);
            let d = 6;
            let q = randn(&mut rng, &[n, d], 0.3);
            let k = randn(&mut rng, &[n, d], 0.3);
            let v = randn(&mut rng, &[n, 3], 1.0);
            let map = rmf::RmfMap::sample(&mut rng, kernel, 32, d, 2.0, 8);
            let phi_q = map.apply(&q);
            let phi_k = map.apply(&k);
            let fast = attention::linear_attention(&phi_q, &phi_k, &v, false, 1e-6);
            // explicit: scores s_ij = phi_q_i . phi_k_j
            let mut slow = Tensor::zeros(&[n, 3]);
            let feat = map.num_features();
            for i in 0..n {
                let mut den = 1e-6f32;
                let mut num = [0.0f32; 3];
                for j in 0..n {
                    let s: f32 = (0..feat)
                        .map(|f| phi_q.data[i * feat + f] * phi_k.data[j * feat + f])
                        .sum();
                    den += s;
                    for c in 0..3 {
                        num[c] += s * v.data[j * 3 + c];
                    }
                }
                for c in 0..3 {
                    slow.data[i * 3 + c] = num[c] / den;
                }
            }
            let diff = fast.max_abs_diff(&slow);
            if diff > 1e-3 {
                return Err(format!("{kernel}: fast vs slow {diff}"));
            }
            Ok(())
        },
    );
}

/// Causal linear attention equals bidirectional restricted to the prefix:
/// row i only depends on positions <= i.
#[test]
fn prop_causal_prefix_consistency() {
    check(
        25,
        |rng| vec![vec![rng.next_u64() as f32, rng.range(2, 9) as f32]],
        |input: &Vec<Vec<f32>>| -> PropResult {
            let mut rng = Rng::new(input[0][0] as u64);
            let n = input[0][1] as usize;
            let feat = 5;
            let phi_q = randn(&mut rng, &[n, feat], 1.0).map(f32::abs);
            let phi_k = randn(&mut rng, &[n, feat], 1.0).map(f32::abs);
            let v = randn(&mut rng, &[n, 2], 1.0);
            let causal = attention::linear_attention(&phi_q, &phi_k, &v, true, 1e-6);
            for i in 0..n {
                // recompute row i from the first i+1 positions only
                let pq = phi_q.slice0(i, 1);
                let pk = phi_k.slice0(0, i + 1);
                let vv = v.slice0(0, i + 1);
                let row = attention::linear_attention(&pq, &pk, &vv, false, 1e-6);
                for c in 0..2 {
                    let a = causal.data[i * 2 + c];
                    let b = row.data[c];
                    if (a - b).abs() > 1e-4 {
                        return Err(format!("row {i} col {c}: {a} vs {b}"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Batcher: over k epochs every sample index is consumed exactly k times.
#[test]
fn prop_batcher_exhaustive_coverage() {
    check(
        25,
        |rng| {
            let len = rng.range(4, 40);
            let batch = rng.range(1, len.min(8));
            vec![vec![len as f32, batch as f32, rng.next_u64() as f32]]
        },
        |input: &Vec<Vec<f32>>| -> PropResult {
            let len = input[0][0] as usize;
            let batch = input[0][1] as usize;
            let seed = input[0][2] as u64;
            let mut b = Batcher::new(len, batch, seed);
            let epochs = 6;
            let draws = epochs * len / batch;
            let mut counts = vec![0usize; len];
            for _ in 0..draws {
                for &i in b.next_batch() {
                    counts[i] += 1;
                }
            }
            let total: usize = counts.iter().sum();
            if total != draws * batch {
                return Err(format!("count total {total} != {}", draws * batch));
            }
            let (lo, hi) = (epochs - 1, epochs + 1);
            for (i, c) in counts.iter().enumerate() {
                if *c < lo || *c > hi {
                    return Err(format!("sample {i} seen {c} times (want ~{epochs})"));
                }
            }
            Ok(())
        },
    );
}

/// BLEU is bounded in [0, 100] and identical sequences score 100.
#[test]
fn prop_bleu_bounds() {
    check(
        40,
        |rng| {
            let n = rng.range(4, 20);
            let hyp: Vec<f32> = (0..n).map(|_| rng.below(12) as f32).collect();
            let m = rng.range(4, 20);
            let refr: Vec<f32> = (0..m).map(|_| rng.below(12) as f32).collect();
            vec![hyp, refr]
        },
        |input: &Vec<Vec<f32>>| -> PropResult {
            let hyp: Vec<u32> = input[0].iter().map(|x| *x as u32).collect();
            let refr: Vec<u32> = input[1].iter().map(|x| *x as u32).collect();
            let s = corpus_bleu(&[(hyp.clone(), refr)]);
            if !(0.0..=100.0 + 1e-9).contains(&s) {
                return Err(format!("bleu {s} out of range"));
            }
            let perfect = corpus_bleu(&[(hyp.clone(), hyp)]);
            if (perfect - 100.0).abs() > 1e-6 {
                return Err(format!("self-bleu {perfect} != 100"));
            }
            Ok(())
        },
    );
}

/// Monte-Carlo RMF estimates are unbiased for every Table-1 kernel
/// (Theorem 1 restricted to the truncated degree law).
#[test]
fn prop_rmf_unbiased_all_kernels() {
    for kernel in Kernel::MACLAURIN {
        let mut rng = Rng::new(0xFEED ^ kernel.name().len() as u64);
        let d = 6;
        let x: Vec<f32> = (0..d).map(|_| rng.normal() * 0.25).collect();
        let y: Vec<f32> = (0..d).map(|_| rng.normal() * 0.25).collect();
        let t: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        let est = rmf::mc_kernel_estimate(&mut rng, kernel, &x, &y, 64, 2.0, 8, 4000);
        let exact = kernel.truncated_value(t as f64, 8).unwrap();
        let tol = 0.08 * exact.abs().max(1.0);
        assert!(
            (est - exact).abs() < tol,
            "{kernel}: est {est} vs exact {exact} (t={t})"
        );
    }
}
