//! The typed attention engine's contract tests:
//!
//! * `Kernel::from_str` is total — bad names are `Err`, never a panic
//!   (acceptance criterion for the typed API).
//! * Streaming decode (`CausalState::append_token`) matches the batched
//!   causal `forward()` token-for-token within 1e-5, for every Table-1
//!   kernel on both the reference and host-fast backends.
//! * Backend dispatch: both compute tiers agree with each other, and
//!   the device tier gates itself off with clean errors on the stub.
//!
//! Pure host math — no PJRT, safe to run multi-threaded.

use std::str::FromStr;

use macformer::attn::{AttentionSpec, Backend, Kernel};
use macformer::tensor::Tensor;
use macformer::util::proptest::{check, PropResult};
use macformer::util::rng::Rng;

fn randn(rng: &mut Rng, shape: &[usize], scale: f32) -> Tensor {
    Tensor::randn(rng, shape, scale)
}

#[test]
fn kernel_parse_is_total() {
    for k in Kernel::ALL {
        assert_eq!(Kernel::from_str(k.name()), Ok(k), "{k} must round-trip");
    }
    for bad in ["bogus", "", "EXP", "exp,inv", "softmax "] {
        assert!(Kernel::from_str(bad).is_err(), "{bad:?} must be a clean Err");
    }
}

/// Streaming decode == batched causal forward, token for token, for
/// every Table-1 kernel and both host backends (the ISSUE's streaming
/// acceptance criterion).
#[test]
fn prop_streaming_decode_matches_batched_causal() {
    check(
        30,
        |rng| {
            let kernel_idx = rng.below(5);
            let backend_idx = rng.below(2);
            let n = rng.range(1, 12);
            let d = rng.range(1, 6);
            let dv = rng.range(1, 5);
            let feat = rng.range(1, 24);
            let seed = rng.next_u64() as f32;
            vec![vec![
                kernel_idx as f32,
                backend_idx as f32,
                n as f32,
                d as f32,
                dv as f32,
                feat as f32,
                seed,
            ]]
        },
        |input: &Vec<Vec<f32>>| -> PropResult {
            let p = &input[0];
            let kernel = Kernel::MACLAURIN[p[0] as usize % 5];
            let backend = if p[1] as usize == 0 { Backend::Reference } else { Backend::HostFast };
            let (n, d, dv, feat) = (
                (p[2] as usize).max(1),
                (p[3] as usize).max(1),
                (p[4] as usize).max(1),
                (p[5] as usize).max(1),
            );
            let seed = p[6] as u64;
            let session = AttentionSpec::new(kernel)
                .head_dim(d)
                .num_features(feat)
                .causal(true)
                .eps(1e-6)
                .seed(seed)
                .backend(backend)
                .build()
                .map_err(|e| format!("build: {e}"))?;
            let mut rng = Rng::new(seed ^ 0xA11CE);
            let q = randn(&mut rng, &[n, d], 0.4);
            let k = randn(&mut rng, &[n, d], 0.4);
            let v = randn(&mut rng, &[n, dv], 1.0);
            let batched = session.forward(&q, &k, &v).map_err(|e| format!("forward: {e}"))?;
            let mut state = session.begin_decode(dv).map_err(|e| format!("decode: {e}"))?;
            for i in 0..n {
                let out = state
                    .append_token(
                        &q.data[i * d..(i + 1) * d],
                        &k.data[i * d..(i + 1) * d],
                        &v.data[i * dv..(i + 1) * dv],
                    )
                    .map_err(|e| format!("token {i}: {e}"))?;
                for (c, (a, b)) in out.iter().zip(&batched.data[i * dv..(i + 1) * dv]).enumerate()
                {
                    // magnitude-scaled like the fastpath_equiv phi
                    // contract: the batched causal path is chunked
                    // (MACFORMER_CHUNK), which regroups the den/num
                    // reductions relative to the streaming fold
                    if (a - b).abs() > 1e-5 * a.abs().max(1.0) {
                        return Err(format!(
                            "{kernel} {backend:?} n={n} d={d} dv={dv} D={feat}: token {i} \
                             col {c}: streaming {a} vs batched {b}"
                        ));
                    }
                }
            }
            if state.len() != n {
                return Err(format!("state consumed {} tokens, expected {n}", state.len()));
            }
            Ok(())
        },
    );
}

/// A long streaming decode session stays consistent with the batched
/// path for every kernel on both backends (deterministic spot check
/// crossing the fastpath's ROW_BLOCK boundary).
#[test]
fn streaming_matches_batched_all_kernels_long_sequence() {
    let (n, d, dv, feat) = (70, 4, 3, 32);
    for kernel in Kernel::MACLAURIN {
        for backend in [Backend::Reference, Backend::HostFast] {
            let session = AttentionSpec::new(kernel)
                .head_dim(d)
                .num_features(feat)
                .causal(true)
                .seed(0xDECADE)
                .backend(backend)
                .build()
                .unwrap();
            let mut rng = Rng::new(0xBEE5 ^ kernel.name().len() as u64);
            let q = randn(&mut rng, &[n, d], 0.4);
            let k = randn(&mut rng, &[n, d], 0.4);
            let v = randn(&mut rng, &[n, dv], 1.0);
            let batched = session.forward(&q, &k, &v).unwrap();
            let mut state = session.begin_decode(dv).unwrap();
            let mut worst = 0.0f32;
            for i in 0..n {
                let out = state
                    .append_token(
                        &q.data[i * d..(i + 1) * d],
                        &k.data[i * d..(i + 1) * d],
                        &v.data[i * dv..(i + 1) * dv],
                    )
                    .unwrap();
                for (a, b) in out.iter().zip(&batched.data[i * dv..(i + 1) * dv]) {
                    // magnitude-scaled: the batched path is chunked
                    worst = worst.max((a - b).abs() / a.abs().max(1.0));
                }
            }
            assert!(worst < 1e-5, "{kernel} {backend:?}: max streaming drift {worst}");
        }
    }
}

/// The two host tiers agree through the dispatch layer: same spec, same
/// seed, same outputs within 1e-5 (phi is bit-for-bit shared).
#[test]
fn prop_backends_agree_through_dispatch() {
    check(
        20,
        |rng| {
            let kernel_idx = rng.below(5);
            let g = rng.range(1, 4);
            let n = rng.range(1, 10);
            let causal = rng.below(2);
            let seed = rng.next_u64() as f32;
            vec![vec![kernel_idx as f32, g as f32, n as f32, causal as f32, seed]]
        },
        |input: &Vec<Vec<f32>>| -> PropResult {
            let p = &input[0];
            let kernel = Kernel::MACLAURIN[p[0] as usize % 5];
            let (g, n) = ((p[1] as usize).max(1), (p[2] as usize).max(1));
            let causal = p[3] as usize == 1;
            let seed = p[4] as u64;
            let (d, dv, feat) = (4, 3, 16);
            let spec = AttentionSpec::new(kernel)
                .head_dim(d)
                .num_features(feat)
                .causal(causal)
                .seed(seed);
            let reference = spec.clone().backend(Backend::Reference).build().unwrap();
            let fast = spec.backend(Backend::HostFast).build().unwrap();
            let mut rng = Rng::new(seed ^ 0xD15C);
            let q = randn(&mut rng, &[g, n, d], 0.4);
            let k = randn(&mut rng, &[g, n, d], 0.4);
            let v = randn(&mut rng, &[g, n, dv], 1.0);
            let a = reference.forward(&q, &k, &v).map_err(|e| e.to_string())?;
            let b = fast.forward(&q, &k, &v).map_err(|e| e.to_string())?;
            // magnitude-scaled elementwise: the host tier's causal path
            // is chunked, so its reductions regroup relative to the
            // reference fold (same contract as the phi comparisons)
            for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
                if (x - y).abs() > 1e-5 * x.abs().max(1.0) {
                    return Err(format!(
                        "{kernel} causal={causal} g={g} n={n}: tiers differ at {i}: {x} vs {y}"
                    ));
                }
            }
            // the quadratic oracle path agrees across tiers too
            let ea = reference.forward_exact(&q, &k, &v).map_err(|e| e.to_string())?;
            let eb = fast.forward_exact(&q, &k, &v).map_err(|e| e.to_string())?;
            let ediff = ea.max_abs_diff(&eb);
            if ediff > 1e-5 {
                return Err(format!("{kernel} causal={causal}: exact paths differ {ediff}"));
            }
            Ok(())
        },
    );
}

/// Interleaving batched `forward()` calls (of several different shapes)
/// with an in-flight streaming decode on ONE session must disturb
/// neither: the session's scratch arena and the decode state's own
/// scratch are disjoint, and buffer reuse across shapes must not leak
/// stale values (the ISSUE's scratch-arena acceptance test).
#[test]
fn interleaved_forward_and_decode_share_a_session_without_bleed() {
    let (n, d, dv, feat) = (70, 4, 3, 16);
    for backend in [Backend::Reference, Backend::HostFast] {
        let spec = AttentionSpec::new(Kernel::Inv)
            .head_dim(d)
            .num_features(feat)
            .causal(true)
            .seed(0xC0FFEE)
            .backend(backend);
        let session = spec.clone().build().unwrap();
        // a pristine twin supplies the expected outputs
        let twin = spec.build().unwrap();

        let mut rng = Rng::new(0x1A7E);
        let q = randn(&mut rng, &[n, d], 0.4);
        let k = randn(&mut rng, &[n, d], 0.4);
        let v = randn(&mut rng, &[n, dv], 1.0);
        let expected = twin.forward(&q, &k, &v).unwrap();

        // side problems of assorted shapes, fired between decode steps
        let q_big = randn(&mut rng, &[3, 33, d], 0.4);
        let k_big = randn(&mut rng, &[3, 33, d], 0.4);
        let v_big = randn(&mut rng, &[3, 33, 5], 1.0);
        let expected_big = twin.forward(&q_big, &k_big, &v_big).unwrap();
        let q_small = randn(&mut rng, &[2, d], 0.4);
        let k_small = randn(&mut rng, &[2, d], 0.4);
        let v_small = randn(&mut rng, &[2, 1], 1.0);
        let expected_small = twin.forward(&q_small, &k_small, &v_small).unwrap();

        let mut state = session.begin_decode(dv).unwrap();
        let mut out_row = vec![0.0f32; dv];
        let mut scratch_out = Tensor { shape: Vec::new(), data: Vec::new() };
        for i in 0..n {
            // hammer the session's forward scratch mid-decode, cycling
            // through growing and shrinking shapes
            match i % 3 {
                0 => {
                    session.forward_into(&q_big, &k_big, &v_big, &mut scratch_out).unwrap();
                    assert!(
                        scratch_out.max_abs_diff(&expected_big) < 1e-5,
                        "{backend:?}: interleaved big forward drifted at token {i}"
                    );
                }
                1 => {
                    session
                        .forward_into(&q_small, &k_small, &v_small, &mut scratch_out)
                        .unwrap();
                    assert!(
                        scratch_out.max_abs_diff(&expected_small) < 1e-5,
                        "{backend:?}: interleaved small forward drifted at token {i}"
                    );
                }
                _ => {}
            }
            state
                .append_token_into(
                    &q.data[i * d..(i + 1) * d],
                    &k.data[i * d..(i + 1) * d],
                    &v.data[i * dv..(i + 1) * dv],
                    &mut out_row,
                )
                .unwrap();
            for (c, (a, b)) in
                out_row.iter().zip(&expected.data[i * dv..(i + 1) * dv]).enumerate()
            {
                assert!(
                    (a - b).abs() < 1e-5,
                    "{backend:?}: token {i} col {c}: streaming {a} vs batched {b}"
                );
            }
        }
        assert_eq!(state.len(), n);
        // the session still matches its twin after all the interleaving
        let after = session.forward(&q, &k, &v).unwrap();
        assert!(after.max_abs_diff(&expected) < 1e-7, "{backend:?}: session state corrupted");
    }
}

#[test]
fn device_backend_gates_off_cleanly() {
    // Building a device session works (the map draw is host-side); every
    // compute op reports a descriptive error instead of panicking.
    let session = AttentionSpec::new(Kernel::Exp)
        .head_dim(4)
        .num_features(8)
        .causal(true)
        .backend(Backend::Device)
        .build()
        .unwrap();
    assert_eq!(session.backend_name(), "device");
    let mut rng = Rng::new(1);
    let q = randn(&mut rng, &[1, 4, 4], 0.5);
    let err = session.forward(&q, &q, &q).unwrap_err();
    assert!(err.to_string().contains("device backend"), "{err}");
    let err = session.begin_decode(4).unwrap_err();
    assert!(err.to_string().contains("device backend"), "{err}");
}

#[test]
fn auto_backend_resolves_to_host_fast_on_this_build() {
    let session = AttentionSpec::new(Kernel::Exp)
        .head_dim(4)
        .num_features(8)
        .backend(Backend::Auto)
        .build()
        .unwrap();
    assert_eq!(session.backend_name(), "host");
    // the resolved name round-trips through the typed parser
    assert_eq!(Backend::from_str(session.backend_name()), Ok(Backend::HostFast));
}
