//! SIMD dispatch-arm control: `set_active` / `reset` are process-global,
//! so this file owns a whole test binary (one test) — flipping arms here
//! cannot race with any other test's reads of the dispatch state.

use macformer::attn::Kernel;
use macformer::fastpath::{simd, FlatRmfMap};
use macformer::reference::rmf::RmfMap;
use macformer::tensor::Tensor;
use macformer::util::rng::Rng;

#[test]
fn arm_switching_controls_the_equivalence_contract() {
    // resolve, then force the scalar arm
    let _ = simd::active();
    assert!(!simd::set_active(false));
    assert!(!simd::active());

    // scalar arm: the flat map is bit-for-bit the reference map
    let mut rng = Rng::new(0x51D);
    let map = RmfMap::sample(&mut rng, Kernel::Exp, 40, 6, 2.0, 8);
    let flat = FlatRmfMap::from(&map);
    let x = Tensor::randn(&mut rng, &[9, 6], 0.5);
    let reference = map.apply(&x);
    let scalar_arm = flat.apply(&x);
    for (i, (a, b)) in reference.data.iter().zip(&scalar_arm.data).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "scalar arm element {i}: {a} vs {b}");
    }

    // vector arm (where the host supports it): within the 1e-5 contract
    let vector_on = simd::set_active(true);
    assert_eq!(vector_on, simd::supported());
    assert_eq!(simd::active(), vector_on);
    if vector_on {
        let vector_arm = flat.apply(&x);
        for (i, (a, b)) in scalar_arm.data.iter().zip(&vector_arm.data).enumerate() {
            assert!(
                (a - b).abs() < 1e-5 * a.abs().max(1.0),
                "vector arm element {i} drifted: {a} vs {b}"
            );
        }
    }

    // reset re-resolves from the environment/CPU without panicking
    simd::reset();
    let resolved = simd::active();
    assert!(resolved == simd::supported() || !resolved);
}
