//! Chunkwise-parallel causal prefill: the equivalence and continuation
//! contract.
//!
//! * the chunked kernel's outputs stay within 1e-5 of the sequential
//!   `(S, z)` fold and the reference oracle for every chunk width
//!   (widths that don't divide n, widths larger than n), and chunk
//!   width 1 *is* the sequential fold, bit for bit;
//! * the running state left by a chunked prefill is **bit-identical**
//!   to the fold's, so `prefill(prompt)` + `append_token`(suffix) is
//!   bit-identical to `append_token`-ing the whole stream — for every
//!   Table-1 kernel, both host backends, several chunk widths;
//! * the `den + eps` normalization guard: a query whose `phi_q . z`
//!   denominator is ~0 produces finite output for every Table-1
//!   kernel on every causal/non-causal/chunked path;
//! * the serve scheduler's prompt prefill leaves streams bit-compatible
//!   with single-stream decode.
//!
//! CI runs this suite on both SIMD dispatch arms (`MACFORMER_NO_SIMD`
//! matrix) and under a `MACFORMER_CHUNK` sweep ({1, 16, 64}). Pure
//! host math — no PJRT, safe to run multi-threaded.

use macformer::attn::{AttentionSpec, Backend, Kernel};
use macformer::fastpath::attention::causal_prefill_fold_into;
use macformer::fastpath::FlatRmfMap;
use macformer::reference::{attention as oracle, rmf::RmfMap};
use macformer::serve::{Scheduler, ServeConfig, StreamPool};
use macformer::tensor::Tensor;
use macformer::util::proptest::{check, PropResult};
use macformer::util::rng::Rng;

fn randn(rng: &mut Rng, shape: &[usize], scale: f32) -> Tensor {
    Tensor::randn(rng, shape, scale)
}

/// Chunked prefill vs the sequential fold vs the oracle, over random
/// shapes and chunk widths (including widths > n and widths that do
/// not divide n). The final `(S, z)` state must be bit-identical to
/// the fold's on every width; outputs within 1e-5 (bit-identical for
/// width 1).
#[test]
fn prop_chunked_prefill_matches_fold_and_oracle() {
    check(
        30,
        |rng| {
            let n = rng.range(1, 40);
            let feat = rng.range(1, 12);
            let dv = rng.range(1, 6);
            let chunk = rng.range(1, 50);
            let seed = rng.next_u64() as f32;
            vec![vec![n as f32, feat as f32, dv as f32, chunk as f32, seed]]
        },
        |input: &Vec<Vec<f32>>| -> PropResult {
            let p = &input[0];
            let (n, feat, dv, chunk) = (
                (p[0] as usize).max(1),
                (p[1] as usize).max(1),
                (p[2] as usize).max(1),
                (p[3] as usize).max(1),
            );
            let mut rng = Rng::new(p[4] as u64);
            let phi_q = randn(&mut rng, &[n, feat], 0.8).map(f32::abs);
            let phi_k = randn(&mut rng, &[n, feat], 0.8).map(f32::abs);
            let v = randn(&mut rng, &[n, dv], 1.0);
            let (pq, pk, vd) = (&phi_q.data[..], &phi_k.data[..], &v.data[..]);
            let mut s_seq = vec![0.0f32; feat * dv];
            let mut z_seq = vec![0.0f32; feat];
            let mut out_seq = vec![0.0f32; n * dv];
            causal_prefill_fold_into(
                pq, pk, vd, n, feat, dv, 1, 1e-6, &mut s_seq, &mut z_seq, &mut out_seq,
            );
            let mut s = vec![0.0f32; feat * dv];
            let mut z = vec![0.0f32; feat];
            let mut out = vec![0.0f32; n * dv];
            causal_prefill_fold_into(
                pq, pk, vd, n, feat, dv, chunk, 1e-6, &mut s, &mut z, &mut out,
            );
            for (i, (a, b)) in s.iter().zip(&s_seq).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("n={n} chunk={chunk}: S elem {i}: {a} vs {b}"));
                }
            }
            for (i, (a, b)) in z.iter().zip(&z_seq).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("n={n} chunk={chunk}: z elem {i}: {a} vs {b}"));
                }
            }
            let ora = oracle::linear_attention(&phi_q, &phi_k, &v, true, 1e-6);
            for (i, (a, b)) in out.iter().zip(&out_seq).enumerate() {
                if chunk <= 1 {
                    if a.to_bits() != b.to_bits() {
                        return Err(format!("chunk 1 must BE the fold: elem {i}: {a} vs {b}"));
                    }
                } else if (a - b).abs() > 1e-5 {
                    return Err(format!(
                        "n={n} feat={feat} dv={dv} chunk={chunk}: elem {i}: {a} vs {b}"
                    ));
                }
                if (a - ora.data[i]).abs() > 1e-5 {
                    return Err(format!(
                        "n={n} chunk={chunk} vs oracle elem {i}: {a} vs {}",
                        ora.data[i]
                    ));
                }
            }
            Ok(())
        },
    );
}

/// The continuation property (the PR's bit-compat acceptance
/// criterion): `prefill(prompt)` followed by `append_token` of a
/// suffix is bit-identical to `append_token`-ing the whole stream —
/// for every Table-1 kernel, both host backends, chunk widths
/// including 1 and widths that do not divide the prompt length.
#[test]
fn prop_prefill_then_decode_equals_full_decode_bitwise() {
    check(
        25,
        |rng| {
            let kernel_idx = rng.below(5);
            let backend_idx = rng.below(2);
            let prompt = rng.range(1, 40);
            let suffix = rng.range(1, 10);
            let d = rng.range(1, 6);
            let dv = rng.range(1, 5);
            let feat = rng.range(1, 24);
            let chunk_idx = rng.below(4);
            let seed = rng.next_u64() as f32;
            vec![vec![
                kernel_idx as f32,
                backend_idx as f32,
                prompt as f32,
                suffix as f32,
                d as f32,
                dv as f32,
                feat as f32,
                chunk_idx as f32,
                seed,
            ]]
        },
        |input: &Vec<Vec<f32>>| -> PropResult {
            let p = &input[0];
            let kernel = Kernel::MACLAURIN[p[0] as usize % 5];
            let backend = if p[1] as usize == 0 { Backend::Reference } else { Backend::HostFast };
            let prompt = (p[2] as usize).max(1);
            let suffix = (p[3] as usize).max(1);
            let d = (p[4] as usize).max(1);
            let dv = (p[5] as usize).max(1);
            let feat = (p[6] as usize).max(1);
            // widths 3 and 16 rarely divide the prompt length; 64 is
            // usually larger than it; 1 is the sequential fold
            let chunk = [1usize, 3, 16, 64][p[7] as usize % 4];
            let seed = p[8] as u64;
            let n = prompt + suffix;
            let sess = AttentionSpec::new(kernel)
                .head_dim(d)
                .num_features(feat)
                .causal(true)
                .seed(seed ^ 0xA5)
                .backend(backend)
                .build()
                .map_err(|e| format!("build: {e}"))?;
            let mut rng = Rng::new(seed);
            let q = randn(&mut rng, &[n, d], 0.5);
            let k = randn(&mut rng, &[n, d], 0.5);
            let v = randn(&mut rng, &[n, dv], 1.0);
            // the whole stream, token by token
            let mut full = sess.begin_decode(dv).map_err(|e| format!("decode: {e}"))?;
            let mut full_rows = vec![0.0f32; n * dv];
            for i in 0..n {
                full.append_token_into(
                    &q.data[i * d..(i + 1) * d],
                    &k.data[i * d..(i + 1) * d],
                    &v.data[i * dv..(i + 1) * dv],
                    &mut full_rows[i * dv..(i + 1) * dv],
                )
                .map_err(|e| format!("append: {e}"))?;
            }
            // prefill the prompt, then stream the suffix
            let mut pre = sess.begin_decode(dv).map_err(|e| format!("decode: {e}"))?;
            let mut prompt_out = vec![0.0f32; prompt * dv];
            pre.prefill_with_chunk_into(
                &q.data[..prompt * d],
                &k.data[..prompt * d],
                &v.data[..prompt * dv],
                chunk,
                &mut prompt_out,
            )
            .map_err(|e| format!("prefill: {e}"))?;
            if pre.len() != prompt {
                return Err(format!("prefill len {} != prompt {prompt}", pre.len()));
            }
            // prompt outputs: chunked contract (bitwise at chunk 1;
            // magnitude-scaled otherwise, like the phi contract)
            for (i, (a, b)) in prompt_out.iter().zip(&full_rows[..prompt * dv]).enumerate() {
                if chunk <= 1 {
                    if a.to_bits() != b.to_bits() {
                        return Err(format!(
                            "{kernel} {backend:?} chunk 1 prompt elem {i}: {a} vs {b}"
                        ));
                    }
                } else if (a - b).abs() > 1e-5 * a.abs().max(1.0) {
                    return Err(format!(
                        "{kernel} {backend:?} chunk {chunk} prompt elem {i}: {a} vs {b}"
                    ));
                }
            }
            // suffix: bit-identical continuation at EVERY chunk width
            let mut row = vec![0.0f32; dv];
            for i in prompt..n {
                pre.append_token_into(
                    &q.data[i * d..(i + 1) * d],
                    &k.data[i * d..(i + 1) * d],
                    &v.data[i * dv..(i + 1) * dv],
                    &mut row,
                )
                .map_err(|e| format!("append: {e}"))?;
                let expect = &full_rows[i * dv..(i + 1) * dv];
                for (j, (a, b)) in row.iter().zip(expect).enumerate() {
                    if a.to_bits() != b.to_bits() {
                        return Err(format!(
                            "{kernel} {backend:?} chunk {chunk} prompt {prompt}: \
                             suffix token {i} elem {j}: {a} vs {b} (state drifted)"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// The `den + eps` normalization guard (regression): a query whose
/// `phi_q . z` denominator is ~0 — here exactly 0, via an all-zero
/// phi_q row against each kernel's real phi_k draw — must produce
/// finite output (no NaN/inf) for every Table-1 kernel on the oracle,
/// the fastpath (causal and non-causal), and every chunked width.
#[test]
fn eps_guard_keeps_vanishing_denominators_finite() {
    let mut rng = Rng::new(0xE9A);
    for kernel in Kernel::MACLAURIN {
        let (n, d, feat, dv) = (9usize, 4usize, 12usize, 3usize);
        let map = RmfMap::sample(&mut rng, kernel, feat, d, 2.0, 8);
        let flat = FlatRmfMap::from(&map);
        let keys = randn(&mut rng, &[n, d], 0.5);
        let phi_k = flat.apply(&keys);
        let phi_q = Tensor::zeros(&[n, feat]);
        let v = randn(&mut rng, &[n, dv], 1.0);
        for causal in [false, true] {
            for out in [
                oracle::linear_attention(&phi_q, &phi_k, &v, causal, 1e-6),
                macformer::fastpath::attention::linear_attention(
                    &phi_q, &phi_k, &v, causal, 1e-6,
                ),
            ] {
                for (i, x) in out.data.iter().enumerate() {
                    assert!(
                        x.is_finite(),
                        "{kernel} causal={causal}: elem {i} = {x} (den+eps guard broken)"
                    );
                }
            }
        }
        for chunk in [1usize, 2, 4, 16] {
            let mut s = vec![0.0f32; feat * dv];
            let mut z = vec![0.0f32; feat];
            let mut out = vec![0.0f32; n * dv];
            let (pq, pk, vd) = (&phi_q.data[..], &phi_k.data[..], &v.data[..]);
            causal_prefill_fold_into(
                pq, pk, vd, n, feat, dv, chunk, 1e-6, &mut s, &mut z, &mut out,
            );
            for (i, x) in out.iter().enumerate() {
                assert!(x.is_finite(), "{kernel} chunk {chunk}: elem {i} = {x}");
            }
        }
    }
}

/// Serve-side prompt prefill: a stream admitted with a prompt through
/// `Scheduler::prefill`, then decoded through ticks, must match a
/// single-stream `prefill_into` + `append_token_into` replay exactly
/// (and the decode suffix must be bit-identical to a no-prefill
/// append-everything replay, proving the serve state is bit-compatible).
#[test]
fn serve_prefill_matches_single_stream_decode() {
    let sess = AttentionSpec::new(Kernel::Exp)
        .head_dim(6)
        .num_features(24)
        .causal(true)
        .seed(31)
        .backend(Backend::HostFast)
        .build()
        .unwrap();
    let (d, dv, prompt, decode) = (6usize, 4usize, 23usize, 8usize);
    let mut rng = Rng::new(0x5E12);
    let n = prompt + decode;
    let q = randn(&mut rng, &[n, d], 0.5);
    let k = randn(&mut rng, &[n, d], 0.5);
    let v = randn(&mut rng, &[n, dv], 1.0);

    // serve path: admit + prefill + ticks
    let mut pool = StreamPool::new(&sess, ServeConfig::new(2, dv)).unwrap();
    let mut sched = Scheduler::new();
    let id = pool.admit().unwrap();
    sched
        .prefill(
            &mut pool,
            id,
            &q.data[..prompt * d],
            &k.data[..prompt * d],
            &v.data[..prompt * dv],
        )
        .unwrap();
    let mut prompt_last = vec![0.0f32; dv];
    pool.take_output(id, &mut prompt_last).unwrap();
    let mut served = vec![0.0f32; decode * dv];
    for t in 0..decode {
        let i = prompt + t;
        pool.submit(
            id,
            &q.data[i * d..(i + 1) * d],
            &k.data[i * d..(i + 1) * d],
            &v.data[i * dv..(i + 1) * dv],
        )
        .unwrap();
        sched.tick(&mut pool).unwrap();
        pool.take_output(id, &mut served[t * dv..(t + 1) * dv]).unwrap();
    }
    assert_eq!(pool.stream_len(id).unwrap(), n);

    // single-stream prefill replay: bit-identical end to end (same
    // chunked kernel, same phi rows)
    let mut state = sess.begin_decode(dv).unwrap();
    let mut prompt_out = vec![0.0f32; prompt * dv];
    state
        .prefill_into(
            &q.data[..prompt * d],
            &k.data[..prompt * d],
            &v.data[..prompt * dv],
            &mut prompt_out,
        )
        .unwrap();
    for (j, (a, b)) in prompt_last.iter().zip(&prompt_out[(prompt - 1) * dv..]).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "prompt last row elem {j}: {a} vs {b}");
    }
    let mut row = vec![0.0f32; dv];
    for t in 0..decode {
        let i = prompt + t;
        state
            .append_token_into(
                &q.data[i * d..(i + 1) * d],
                &k.data[i * d..(i + 1) * d],
                &v.data[i * dv..(i + 1) * dv],
                &mut row,
            )
            .unwrap();
        for (j, (a, b)) in served[t * dv..(t + 1) * dv].iter().zip(&row).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "decode token {t} elem {j}: {a} vs {b}");
        }
    }

    // and against a never-prefilled stream: the decode suffix is still
    // bit-identical (state bit-compat), the prompt row within 1e-5
    let mut scratch = sess.begin_decode(dv).unwrap();
    for i in 0..n {
        scratch
            .append_token_into(
                &q.data[i * d..(i + 1) * d],
                &k.data[i * d..(i + 1) * d],
                &v.data[i * dv..(i + 1) * dv],
                &mut row,
            )
            .unwrap();
        if i == prompt - 1 {
            for (j, (a, b)) in prompt_last.iter().zip(&row).enumerate() {
                // chunked-vs-fold contract, magnitude-scaled
                assert!(
                    (a - b).abs() < 1e-5 * b.abs().max(1.0),
                    "prompt last row elem {j}: {a} vs {b}"
                );
            }
        }
        if i >= prompt {
            let t = i - prompt;
            for (j, (a, b)) in served[t * dv..(t + 1) * dv].iter().zip(&row).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "decode token {t} elem {j} vs scratch decode: {a} vs {b}"
                );
            }
        }
    }
}
