//! Cross-implementation numerics: the compiled HLO micro modules vs the
//! pure-Rust reference math (reference::attention) on identical inputs.
//!
//! This is the strongest correctness statement in the stack: three
//! independent implementations (Pallas-lowered HLO, pure jnp [tested in
//! pytest], pure Rust) agree on the paper's quantities.
//!
//! One #[test] = one process = one PJRT client (see pjrt_smoke.rs).

mod common;

use common::registry_or_skip;
use macformer::metrics::nmse;
use macformer::reference::attention;
use macformer::runtime::{Executable, HostArg};
use macformer::tensor::Tensor;
use macformer::util::rng::Rng;

/// Host-side preSBN mirroring compile/ppsbn.py (max_row mode) for the
/// micro modules' (B, H, n, d) layout flattened as (G, n, d).
fn pre_sbn_host(x: &mut [f32], g: usize, n: usize, d: usize, eps: f32) {
    let (b, h) = (16usize, 8usize);
    assert_eq!(b * h, g);
    // batch-norm stats over (batch, seq) per (head, channel)
    for head in 0..h {
        for c in 0..d {
            let mut mean = 0.0f64;
            let mut count = 0.0f64;
            for bi in 0..b {
                let base = (bi * h + head) * n * d;
                for i in 0..n {
                    mean += x[base + i * d + c] as f64;
                    count += 1.0;
                }
            }
            mean /= count;
            let mut var = 0.0f64;
            for bi in 0..b {
                let base = (bi * h + head) * n * d;
                for i in 0..n {
                    let v = x[base + i * d + c] as f64 - mean;
                    var += v * v;
                }
            }
            var /= count;
            let denom = (var + eps as f64).sqrt();
            for bi in 0..b {
                let base = (bi * h + head) * n * d;
                for i in 0..n {
                    let idx = base + i * d + c;
                    x[idx] = ((x[idx] as f64 - mean) / denom) as f32;
                }
            }
        }
    }
    // max row norm per (batch, head) matrix
    for gi in 0..g {
        let base = gi * n * d;
        let mut maxn = 0.0f32;
        for i in 0..n {
            let row = &x[base + i * d..base + (i + 1) * d];
            let nn: f32 = row.iter().map(|v| v * v).sum::<f32>().sqrt();
            maxn = maxn.max(nn);
        }
        let denom = maxn + eps;
        for v in &mut x[base..base + n * d] {
            *v /= denom;
        }
    }
}

#[test]
fn hlo_micro_modules_match_rust_reference() {
    let Some(reg) = registry_or_skip() else { return };
    let n = 256;
    let d = 64;
    let g = 16 * 8;
    let mut rng = Rng::new(99);
    let numel = g * n * d;
    let gen = |rng: &mut Rng| -> Vec<f32> { (0..numel).map(|_| rng.normal() * 0.5).collect() };
    let (q, k, v) = (gen(&mut rng), gen(&mut rng), gen(&mut rng));
    let dims = vec![g, n, d];

    // --- exact softmax module vs rust reference ---------------------------
    let sm_info = reg.get("micro.softmax.n256").unwrap();
    let sm = Executable::compile_file(&sm_info.name, &reg.hlo_path(sm_info)).unwrap();
    let outs = sm
        .run_hosts(&[
            HostArg::F32(dims.clone(), q.clone()),
            HostArg::F32(dims.clone(), k.clone()),
            HostArg::F32(dims.clone(), v.clone()),
        ])
        .unwrap();
    let hlo_out = Executable::fetch_f32(&outs[0]).unwrap();
    assert_eq!(hlo_out.len(), numel);

    // host reference: preSBN then per-problem exact softmax attention
    let (mut qs, mut ks) = (q.clone(), k.clone());
    pre_sbn_host(&mut qs, g, n, d, 1e-12);
    pre_sbn_host(&mut ks, g, n, d, 1e-12);
    let mut ref_out = vec![0.0f32; numel];
    for gi in 0..g {
        let sl = |x: &[f32]| {
            Tensor::from_vec(&[n, d], x[gi * n * d..(gi + 1) * n * d].to_vec())
        };
        let out = attention::softmax_attention(&sl(&qs), &sl(&ks), &sl(&v), false);
        ref_out[gi * n * d..(gi + 1) * n * d].copy_from_slice(&out.data);
    }
    let err = nmse(&hlo_out, &ref_out);
    assert!(err < 1e-6, "softmax HLO vs rust reference NMSE {err}");

    // --- RMFA module approximates the softmax module ------------------------
    // Theorem-level check at module granularity: with D=256 features the
    // approximation error must be small and must shrink as D grows.
    let mut errs = Vec::new();
    for feat in [64usize, 256] {
        let rm_info = reg.get(&format!("micro.rmfa_exp.n256.D{feat}")).unwrap();
        let rm = Executable::compile_file(&rm_info.name, &reg.hlo_path(rm_info)).unwrap();
        // average over a few omega draws to beat single-draw variance
        let mut acc = vec![0.0f64; numel];
        let draws = 3;
        for s in 0..draws {
            let outs = rm
                .run_hosts(&[
                    HostArg::F32(dims.clone(), q.clone()),
                    HostArg::F32(dims.clone(), k.clone()),
                    HostArg::F32(dims.clone(), v.clone()),
                    HostArg::key([1234, s]),
                ])
                .unwrap();
            for (a, x) in acc.iter_mut().zip(Executable::fetch_f32(&outs[0]).unwrap()) {
                *a += x as f64 / draws as f64;
            }
        }
        let approx: Vec<f32> = acc.iter().map(|x| *x as f32).collect();
        let err = nmse(&approx, &hlo_out);
        errs.push(err);
    }
    assert!(
        errs[1] < errs[0],
        "error must shrink with D: D=64 {} vs D=256 {}",
        errs[0],
        errs[1]
    );
    assert!(errs[1] < 0.5, "D=256 RMFA too far from softmax: {}", errs[1]);
}
