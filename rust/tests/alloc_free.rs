//! Steady-state allocation audit (the ISSUE's heap-profile acceptance
//! criterion): after a warmup call, `AttentionSession::forward_into`,
//! `CausalState::append_token_into`, the serve subsystem's
//! submit/tick/take_output loop, and `serve::obs` span recording must
//! make ZERO heap allocations — the scratch arena, the thread-local
//! kernel workspaces, the claim-based worker pool, the scheduler's
//! grow-only gather buffers, the fixed-bucket telemetry, and the
//! fixed-capacity span rings leave nothing to allocate per call.
//!
//! A counting `#[global_allocator]` wraps the system allocator; this
//! file owns its whole test binary so the counter sees only this
//! file's traffic. Counts are compared per-test around hot loops, so
//! the harness's own allocations (test names, result channels) stay
//! outside the measured window. `MACFORMER_THREADS` is deliberately
//! left alone: the multi-problem test exercises the persistent pool
//! path itself, which must also be allocation-free in steady state.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use macformer::attn::{AttentionSpec, Backend, Kernel};
use macformer::serve::obs::{self, Stage};
use macformer::serve::{ResilienceConfig, Scheduler, ServeConfig, StreamPool, Supervisor};
use macformer::tensor::Tensor;
use macformer::util::rng::Rng;

/// The allocation counter is process-global, so the tests in this
/// binary serialize on one lock — otherwise one test's warmup traffic
/// would land in another's measured window.
static TEST_LOCK: Mutex<()> = Mutex::new(());

struct CountingAllocator;

static ALLOCATIONS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAllocator = CountingAllocator;

fn allocations() -> usize {
    ALLOCATIONS.load(Ordering::SeqCst)
}

/// Single-problem forward (count = 1 runs on the calling thread): the
/// strictest window — no pool involved at all.
#[test]
fn forward_into_single_problem_is_allocation_free_after_warmup() {
    let _serial = TEST_LOCK.lock().unwrap();
    let session = AttentionSpec::new(Kernel::Exp)
        .head_dim(8)
        .num_features(32)
        .seed(5)
        .backend(Backend::HostFast)
        .build()
        .unwrap();
    let mut rng = Rng::new(3);
    let q = Tensor::randn(&mut rng, &[1, 24, 8], 0.5);
    let k = Tensor::randn(&mut rng, &[1, 24, 8], 0.5);
    let v = Tensor::randn(&mut rng, &[1, 24, 6], 1.0);
    let mut out = Tensor { shape: Vec::new(), data: Vec::new() };
    // warmup: scratch arena + thread-local workspaces grow here
    for _ in 0..3 {
        session.forward_into(&q, &k, &v, &mut out).unwrap();
    }
    let before = allocations();
    for _ in 0..10 {
        session.forward_into(&q, &k, &v, &mut out).unwrap();
    }
    let after = allocations();
    assert_eq!(
        after - before,
        0,
        "steady-state single-problem forward_into allocated {} times",
        after - before
    );
    // sanity: the outputs are still real numbers
    assert!(out.data.iter().all(|x| x.is_finite()));
}

/// Batched forward across the persistent worker pool: claim-based
/// dispatch is POD-only, so the pooled path must also be quiet once the
/// workers' thread-local scratch has warmed up.
#[test]
fn forward_into_batched_through_the_pool_is_allocation_free_after_warmup() {
    let _serial = TEST_LOCK.lock().unwrap();
    let session = AttentionSpec::new(Kernel::Inv)
        .head_dim(8)
        .num_features(24)
        .seed(6)
        .backend(Backend::HostFast)
        .build()
        .unwrap();
    let mut rng = Rng::new(4);
    let q = Tensor::randn(&mut rng, &[6, 64, 8], 0.5);
    let k = Tensor::randn(&mut rng, &[6, 64, 8], 0.5);
    let v = Tensor::randn(&mut rng, &[6, 64, 4], 1.0);
    let mut out = Tensor { shape: Vec::new(), data: Vec::new() };
    // warmup: pool spawn + every worker's thread-local scratch
    for _ in 0..20 {
        session.forward_into(&q, &k, &v, &mut out).unwrap();
    }
    // Claiming is dynamic, so a cold worker could in principle first
    // participate after the warmup loop; demonstrating ONE fully
    // allocation-free window is the steady-state criterion.
    let mut zero_window = false;
    for _attempt in 0..5 {
        let before = allocations();
        for _ in 0..10 {
            session.forward_into(&q, &k, &v, &mut out).unwrap();
        }
        if allocations() == before {
            zero_window = true;
            break;
        }
    }
    assert!(
        zero_window,
        "pooled forward_into never reached an allocation-free steady state"
    );
}

/// The serve loop: once every stream slot, the scheduler's gather
/// scratch, and the worker-pool thread locals have warmed up, a full
/// submit-all / tick / take-all cycle over the micro-batching scheduler
/// allocates nothing (the ISSUE's steady-state serving criterion).
#[test]
fn serve_tick_cycle_is_allocation_free_after_warmup() {
    let _serial = TEST_LOCK.lock().unwrap();
    let session = AttentionSpec::new(Kernel::Exp)
        .head_dim(8)
        .num_features(32)
        .causal(true)
        .seed(9)
        .backend(Backend::HostFast)
        .build()
        .unwrap();
    let (d, dv, streams) = (8usize, 4usize, 8usize);
    let mut pool = StreamPool::new(&session, ServeConfig::new(streams, dv)).unwrap();
    let mut scheduler = Scheduler::new();
    let ids: Vec<_> = (0..streams).map(|_| pool.admit().unwrap()).collect();
    let mut rng = Rng::new(6);
    let q = Tensor::randn(&mut rng, &[streams, d], 0.4);
    let k = Tensor::randn(&mut rng, &[streams, d], 0.4);
    let v = Tensor::randn(&mut rng, &[streams, dv], 1.0);
    let mut row = vec![0.0f32; dv];
    let mut cycle = |pool: &mut StreamPool<'_>, scheduler: &mut Scheduler| {
        for (i, &id) in ids.iter().enumerate() {
            pool.submit(
                id,
                &q.data[i * d..(i + 1) * d],
                &k.data[i * d..(i + 1) * d],
                &v.data[i * dv..(i + 1) * dv],
            )
            .unwrap();
        }
        let stats = scheduler.tick(pool).unwrap();
        assert_eq!(stats.batch, streams);
        for &id in &ids {
            pool.take_output(id, &mut row).unwrap();
        }
    };
    // warmup: scheduler scratch + every pool worker's thread locals
    for _ in 0..20 {
        cycle(&mut pool, &mut scheduler);
    }
    // claiming is dynamic (see the batched forward test): demonstrate
    // ONE fully allocation-free window
    let mut zero_window = false;
    for _attempt in 0..5 {
        let before = allocations();
        for _ in 0..10 {
            cycle(&mut pool, &mut scheduler);
        }
        if allocations() == before {
            zero_window = true;
            break;
        }
    }
    assert!(
        zero_window,
        "steady-state serve submit/tick/take cycle never reached an allocation-free window"
    );
    assert!(row.iter().all(|x| x.is_finite()));
}

/// The supervised serve loop with every resilience deadline armed: the
/// per-tick deadline sweep walks the whole entry table checking
/// idle-hibernate, output-expiry, and governor state, and — as long as
/// no deadline actually fires — a full supervised submit / tick / take
/// cycle must allocate exactly as little as the bare pool + scheduler:
/// nothing. (The deadlines here are huge, so the sweep runs its
/// comparisons every tick without ever evicting.)
#[test]
fn supervised_tick_with_armed_deadlines_is_allocation_free_after_warmup() {
    let _serial = TEST_LOCK.lock().unwrap();
    let session = AttentionSpec::new(Kernel::Exp)
        .head_dim(8)
        .num_features(32)
        .causal(true)
        .seed(17)
        .backend(Backend::HostFast)
        .build()
        .unwrap();
    let (d, dv, streams) = (8usize, 4usize, 8usize);
    let resilience = ResilienceConfig {
        // armed (sweep runs every tick) but never firing in this loop
        idle_hibernate_ticks: 1 << 40,
        hibernate_expire_ticks: 1 << 40,
        output_deadline_ticks: 1 << 40,
        shed_pending: usize::MAX,
        ..ResilienceConfig::default()
    };
    let mut sup = Supervisor::new(&session, ServeConfig::new(streams, dv), resilience).unwrap();
    let ids: Vec<_> = (0..streams).map(|_| sup.open().unwrap()).collect();
    let mut rng = Rng::new(14);
    let q = Tensor::randn(&mut rng, &[streams, d], 0.4);
    let k = Tensor::randn(&mut rng, &[streams, d], 0.4);
    let v = Tensor::randn(&mut rng, &[streams, dv], 1.0);
    let mut row = vec![0.0f32; dv];
    let mut cycle = |sup: &mut Supervisor<'_>| {
        for (i, &id) in ids.iter().enumerate() {
            sup.submit(
                id,
                &q.data[i * d..(i + 1) * d],
                &k.data[i * d..(i + 1) * d],
                &v.data[i * dv..(i + 1) * dv],
            )
            .unwrap();
        }
        let stats = sup.tick().unwrap();
        assert_eq!(stats.batch, streams);
        assert_eq!(stats.faulted, 0);
        for &id in &ids {
            sup.take_output(id, &mut row).unwrap();
        }
    };
    // warmup: scheduler scratch + every pool worker's thread locals
    for _ in 0..20 {
        cycle(&mut sup);
    }
    // claiming is dynamic (see the batched forward test): demonstrate
    // ONE fully allocation-free window
    let mut zero_window = false;
    for _attempt in 0..5 {
        let before = allocations();
        for _ in 0..10 {
            cycle(&mut sup);
        }
        if allocations() == before {
            zero_window = true;
            break;
        }
    }
    assert!(
        zero_window,
        "supervised submit/tick/take cycle with armed deadlines never reached \
         an allocation-free window"
    );
    assert!(row.iter().all(|x| x.is_finite()));
}

/// Chunked prompt prefill + streaming decode: after one warmup prefill
/// per prompt shape (state-owned grow-only staging + the chunked
/// kernel's thread-local scratch), a full `reset` + `prefill_into` +
/// decode window makes ZERO heap allocations.
#[test]
fn prefill_then_decode_window_is_allocation_free_after_warmup() {
    let _serial = TEST_LOCK.lock().unwrap();
    let session = AttentionSpec::new(Kernel::Exp)
        .head_dim(8)
        .num_features(32)
        .causal(true)
        .seed(11)
        .backend(Backend::HostFast)
        .build()
        .unwrap();
    let (d, dv, prompt, decode) = (8usize, 4usize, 70usize, 16usize);
    let mut rng = Rng::new(8);
    let n = prompt + decode;
    let q = Tensor::randn(&mut rng, &[n, d], 0.4);
    let k = Tensor::randn(&mut rng, &[n, d], 0.4);
    let v = Tensor::randn(&mut rng, &[n, dv], 1.0);
    let mut state = session.begin_decode(dv).unwrap();
    let mut prompt_out = vec![0.0f32; prompt * dv];
    let mut row = vec![0.0f32; dv];
    let mut cycle = |state: &mut macformer::attn::CausalState<'_>| {
        state.reset();
        state
            .prefill_into(
                &q.data[..prompt * d],
                &k.data[..prompt * d],
                &v.data[..prompt * dv],
                &mut prompt_out,
            )
            .unwrap();
        for i in prompt..n {
            state
                .append_token_into(
                    &q.data[i * d..(i + 1) * d],
                    &k.data[i * d..(i + 1) * d],
                    &v.data[i * dv..(i + 1) * dv],
                    &mut row,
                )
                .unwrap();
        }
    };
    // warmup: state staging + chunk workspace + pool worker thread locals
    for _ in 0..10 {
        cycle(&mut state);
    }
    // claiming across the pool is dynamic (see the batched forward
    // test): demonstrate ONE fully allocation-free window
    let mut zero_window = false;
    for _attempt in 0..5 {
        let before = allocations();
        for _ in 0..5 {
            cycle(&mut state);
        }
        if allocations() == before {
            zero_window = true;
            break;
        }
    }
    assert!(
        zero_window,
        "steady-state prefill + decode window never reached an allocation-free state"
    );
    assert!(prompt_out.iter().all(|x| x.is_finite()));
    assert_eq!(state.len(), n);
}

/// Serve prompt admission: once the scheduler's prefill scratch and the
/// slot states are warm, a full retire / admit / prefill / take /
/// decode-tick cycle allocates nothing.
#[test]
fn serve_prefill_cycle_is_allocation_free_after_warmup() {
    let _serial = TEST_LOCK.lock().unwrap();
    let session = AttentionSpec::new(Kernel::Exp)
        .head_dim(8)
        .num_features(32)
        .causal(true)
        .seed(13)
        .backend(Backend::HostFast)
        .build()
        .unwrap();
    let (d, dv, prompt) = (8usize, 4usize, 40usize);
    let mut pool = StreamPool::new(&session, ServeConfig::new(2, dv)).unwrap();
    let mut scheduler = Scheduler::new();
    let mut rng = Rng::new(9);
    let pq = Tensor::randn(&mut rng, &[prompt, d], 0.4);
    let pk = Tensor::randn(&mut rng, &[prompt, d], 0.4);
    let pv = Tensor::randn(&mut rng, &[prompt, dv], 1.0);
    let q1 = Tensor::randn(&mut rng, &[1, d], 0.4);
    let k1 = Tensor::randn(&mut rng, &[1, d], 0.4);
    let v1 = Tensor::randn(&mut rng, &[1, dv], 1.0);
    let mut row = vec![0.0f32; dv];
    let mut cycle = |pool: &mut StreamPool<'_>, scheduler: &mut Scheduler| {
        let id = pool.admit().unwrap();
        scheduler.prefill(pool, id, &pq.data, &pk.data, &pv.data).unwrap();
        pool.take_output(id, &mut row).unwrap();
        pool.submit(id, &q1.data, &k1.data, &v1.data).unwrap();
        scheduler.tick(pool).unwrap();
        pool.take_output(id, &mut row).unwrap();
        pool.retire(id).unwrap();
    };
    for _ in 0..10 {
        cycle(&mut pool, &mut scheduler);
    }
    let mut zero_window = false;
    for _attempt in 0..5 {
        let before = allocations();
        for _ in 0..5 {
            cycle(&mut pool, &mut scheduler);
        }
        if allocations() == before {
            zero_window = true;
            break;
        }
    }
    assert!(
        zero_window,
        "steady-state serve admit/prefill/decode cycle never reached an allocation-free window"
    );
    assert!(row.iter().all(|x| x.is_finite()));
}

/// Streaming decode: after `begin_decode` (which owns all per-token
/// scratch), `append_token_into` is allocation-free from token one.
#[test]
fn append_token_into_is_allocation_free() {
    let _serial = TEST_LOCK.lock().unwrap();
    let session = AttentionSpec::new(Kernel::Exp)
        .head_dim(8)
        .num_features(32)
        .causal(true)
        .seed(7)
        .backend(Backend::HostFast)
        .build()
        .unwrap();
    let mut rng = Rng::new(5);
    let d = 8;
    let dv = 4;
    let n = 64;
    let q = Tensor::randn(&mut rng, &[n, d], 0.4);
    let k = Tensor::randn(&mut rng, &[n, d], 0.4);
    let v = Tensor::randn(&mut rng, &[n, dv], 1.0);
    let mut state = session.begin_decode(dv).unwrap();
    let mut row = vec![0.0f32; dv];
    // warmup: the first tokens touch the thread-local phi scratch
    for i in 0..4 {
        state
            .append_token_into(
                &q.data[i * d..(i + 1) * d],
                &k.data[i * d..(i + 1) * d],
                &v.data[i * dv..(i + 1) * dv],
                &mut row,
            )
            .unwrap();
    }
    let before = allocations();
    for i in 4..n {
        state
            .append_token_into(
                &q.data[i * d..(i + 1) * d],
                &k.data[i * d..(i + 1) * d],
                &v.data[i * dv..(i + 1) * dv],
                &mut row,
            )
            .unwrap();
    }
    let after = allocations();
    assert_eq!(
        after - before,
        0,
        "steady-state append_token_into allocated {} times",
        after - before
    );
    assert_eq!(state.len(), n);
}

/// Span recording rides every hot stage (tick gather, phi GEMM, state
/// fold, SSE writes), so after the one-time ring registration it must
/// be strictly allocation-free: histogram updates are relaxed atomics
/// and the ring overwrites a pre-reserved fixed-capacity buffer. Both
/// the explicit `record_span` call and the drop-guard [`obs::span`]
/// path are measured, with a request id installed so the id plumbing
/// is inside the window too.
#[test]
fn span_recording_is_allocation_free_after_registration() {
    let _serial = TEST_LOCK.lock().unwrap();
    obs::set_enabled(true);
    obs::set_request_id(obs::hash_request_id(b"alloc-free-probe"));
    // warmup: registers this thread's span ring (the one bounded
    // allocation) and touches every stage's histogram once
    obs::register_thread();
    for stage in Stage::ALL {
        let t0 = obs::now_ns();
        obs::record_span(stage, t0, t0 + 100, 1);
    }
    let before = allocations();
    // far past RING_CAP so the window covers both the fill phase
    // (pushes into reserved capacity) and the wrap-around overwrites
    for i in 0..3 * obs::RING_CAP {
        let stage = Stage::ALL[i % Stage::ALL.len()];
        let t0 = obs::now_ns();
        obs::record_span(stage, t0, t0 + 100, 1);
        drop(obs::span(stage));
    }
    let after = allocations();
    obs::set_request_id(0);
    assert_eq!(
        after - before,
        0,
        "steady-state span recording allocated {} times",
        after - before
    );
    // sanity: the spans actually landed
    assert!(obs::snapshot(Stage::PhiGemm).count >= (3 * obs::RING_CAP / Stage::ALL.len()) as u64);
}
