//! Multi-stream serving correctness on the default SIMD dispatch arm
//! (CI re-runs this whole binary with `MACFORMER_NO_SIMD=1` to pin the
//! scalar arm; `tests/serve_arms.rs` additionally pins each arm
//! in-process).
//!
//! The core property: N streams interleaved through the serve
//! subsystem — random admission order, random per-tick participation,
//! micro-batched and sequential-fallback ticks mixed — produce
//! per-token outputs **bit-identical** to N independent single-stream
//! `CausalState` decodes of the same token streams. Plus the typed
//! admission-control/backpressure behaviors of the pool.

use std::str::FromStr;

use macformer::attn::{AttentionSpec, Backend, Kernel};
use macformer::serve::{Scheduler, ServeConfig, ServeError, StreamPool};
use macformer::util::proptest::{check, PropResult};
use macformer::util::rng::Rng;

fn build_session(
    kernel: Kernel,
    backend: Backend,
    d: usize,
    feat: usize,
    seed: u64,
) -> macformer::attn::AttentionSession {
    AttentionSpec::new(kernel)
        .head_dim(d)
        .num_features(feat)
        .causal(true)
        .eps(1e-6)
        .seed(seed)
        .backend(backend)
        .build()
        .unwrap()
}

/// N interleaved serve streams == N independent single-stream decodes,
/// bit for bit, across kernels, backends, batch thresholds, and ragged
/// per-tick participation.
#[test]
fn prop_interleaved_serve_streams_match_single_stream_decode() {
    check(
        25,
        |rng| {
            vec![vec![
                rng.below(5) as f32,            // kernel
                rng.below(2) as f32,            // backend
                rng.range(1, 7) as f32,         // streams
                rng.range(1, 9) as f32,         // tokens per stream
                rng.range(1, 6) as f32,         // d
                rng.range(1, 5) as f32,         // dv
                rng.range(1, 24) as f32,        // feat
                rng.range(1, 5) as f32,         // min_batch
                (rng.next_u32() >> 8) as f32,   // seed (exact in f32)
            ]]
        },
        |input: &Vec<Vec<f32>>| -> PropResult {
            // shrink candidates may drop elements; a truncated input is
            // vacuously fine
            let Some(p) = input.first() else { return Ok(()) };
            if p.len() < 9 {
                return Ok(());
            }
            let kernel = Kernel::MACLAURIN[p[0] as usize % 5];
            let backend = if p[1] as usize == 0 { Backend::Reference } else { Backend::HostFast };
            let streams = (p[2] as usize).max(1);
            let tokens = (p[3] as usize).max(1);
            let d = (p[4] as usize).max(1);
            let dv = (p[5] as usize).max(1);
            let feat = (p[6] as usize).max(1);
            let min_batch = (p[7] as usize).max(1);
            let seed = p[8] as u64;
            let session = build_session(kernel, backend, d, feat, seed);
            let cfg = ServeConfig { min_batch, ..ServeConfig::new(streams, dv) };
            let mut pool = StreamPool::new(&session, cfg).map_err(|e| format!("pool: {e}"))?;
            let mut scheduler = Scheduler::new();

            // pre-generate every stream's tokens
            let mut rng = Rng::new(seed ^ 0x5E44E);
            let stride = 2 * d + dv;
            let data: Vec<Vec<f32>> = (0..streams)
                .map(|_| (0..tokens * stride).map(|_| rng.normal() * 0.5).collect())
                .collect();

            // interleaved serve pass: random subset of ready streams
            // submits each tick
            let ids: Vec<_> = (0..streams)
                .map(|i| pool.admit().map_err(|e| format!("admit {i}: {e}")))
                .collect::<Result<_, _>>()?;
            let mut produced = vec![0usize; streams];
            let mut in_flight = vec![false; streams];
            let mut outs = vec![vec![0.0f32; tokens * dv]; streams];
            let mut guard = 0usize;
            while produced.iter().any(|&t| t < tokens) {
                guard += 1;
                if guard > 64 * (tokens + streams) {
                    return Err("livelock: no progress".into());
                }
                for i in 0..streams {
                    if in_flight[i] || produced[i] >= tokens {
                        continue;
                    }
                    // ragged participation: ~70% of ready streams per
                    // tick (idle ticks are legal too)
                    if !rng.bernoulli(0.7) {
                        continue;
                    }
                    let t = produced[i];
                    let row = &data[i][t * stride..(t + 1) * stride];
                    pool.submit(ids[i], &row[..d], &row[d..2 * d], &row[2 * d..])
                        .map_err(|e| format!("submit {i}@{t}: {e}"))?;
                    in_flight[i] = true;
                }
                scheduler.tick(&mut pool).map_err(|e| format!("tick: {e}"))?;
                for i in 0..streams {
                    if !in_flight[i] {
                        continue;
                    }
                    let t = produced[i];
                    pool.take_output(ids[i], &mut outs[i][t * dv..(t + 1) * dv])
                        .map_err(|e| format!("take {i}@{t}: {e}"))?;
                    produced[i] = t + 1;
                    in_flight[i] = false;
                }
            }
            for (i, &id) in ids.iter().enumerate() {
                if pool.stream_len(id) != Ok(tokens) {
                    return Err(format!("stream {i} len {:?} != {tokens}", pool.stream_len(id)));
                }
                pool.retire(id).map_err(|e| format!("retire {i}: {e}"))?;
            }
            if pool.telemetry().tokens() != (streams * tokens) as u64 {
                return Err(format!(
                    "telemetry counted {} tokens, expected {}",
                    pool.telemetry().tokens(),
                    streams * tokens
                ));
            }

            // independent single-stream decodes must match bit for bit
            let mut row = vec![0.0f32; dv];
            for i in 0..streams {
                let mut state = session.begin_decode(dv).map_err(|e| format!("decode: {e}"))?;
                for t in 0..tokens {
                    let tok = &data[i][t * stride..(t + 1) * stride];
                    state
                        .append_token_into(&tok[..d], &tok[d..2 * d], &tok[2 * d..], &mut row)
                        .map_err(|e| format!("single {i}@{t}: {e}"))?;
                    let served = outs[i][t * dv..(t + 1) * dv].iter();
                    for (c, (a, b)) in served.zip(&row).enumerate() {
                        if a.to_bits() != b.to_bits() {
                            return Err(format!(
                                "{kernel} {backend:?} streams={streams} tokens={tokens} d={d} \
                                 dv={dv} D={feat} min_batch={min_batch}: stream {i} token {t} \
                                 col {c}: serve {a} vs single-stream {b}"
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// 64 concurrent streams through the micro-batched host tier — the
/// ISSUE's sustained-load shape — stay bit-identical to single-stream
/// decode (deterministic spot check; the bench runs the same load with
/// telemetry).
#[test]
fn serve_sustains_64_streams_bit_identical() {
    use macformer::serve::loadgen::{run, Arrival, LoadConfig};
    let report = run(&LoadConfig {
        streams: 64,
        tokens: 12,
        head_dim: 8,
        dv: 6,
        num_features: 32,
        arrival: Arrival::Closed,
        seed: 0x5EED,
        ..LoadConfig::default()
    })
    .unwrap();
    assert_eq!(report.tokens_total, 64 * 12);
    assert_eq!(report.stream_errors, 0);
    assert_eq!(report.verified, Some(true), "max |diff| {}", report.max_abs_diff);
    // the closed pattern must actually exercise the batched path
    assert!(report.telemetry.batched_ticks() > 0);
    assert_eq!(report.telemetry.max_batch(), 64);
}

/// The CLI's --arrival values parse and the staggered ramp exercises
/// both scheduler paths in one run.
#[test]
fn staggered_ramp_mixes_sequential_and_batched_ticks() {
    use macformer::serve::loadgen::{run, Arrival, LoadConfig};
    assert!(macformer::serve::Arrival::from_str("staggered").is_ok());
    let report = run(&LoadConfig {
        streams: 6,
        tokens: 8,
        head_dim: 4,
        dv: 3,
        num_features: 16,
        arrival: Arrival::Staggered,
        min_batch: 3,
        seed: 3,
        ..LoadConfig::default()
    })
    .unwrap();
    assert_eq!(report.verified, Some(true));
    assert!(report.telemetry.sequential_ticks() > 0);
    assert!(report.telemetry.batched_ticks() > 0);
}

/// Admission control rejects with typed reasons, never panics, and the
/// queue bound produces real backpressure under load.
#[test]
fn backpressure_and_stale_handles_are_clean_errors() {
    let session = build_session(Kernel::Exp, Backend::HostFast, 4, 16, 9);
    let cfg = ServeConfig { max_pending: 1, ..ServeConfig::new(2, 2) };
    let mut pool = StreamPool::new(&session, cfg).unwrap();
    let mut scheduler = Scheduler::new();
    let a = pool.admit().unwrap();
    let b = pool.admit().unwrap();
    assert!(matches!(pool.admit().unwrap_err(), ServeError::PoolFull { capacity: 2 }));
    pool.submit(a, &[0.1; 4], &[0.2; 4], &[1.0, 2.0]).unwrap();
    // the queue bound (1) pushes back on the second stream this tick
    let err = pool.submit(b, &[0.1; 4], &[0.2; 4], &[1.0, 2.0]).unwrap_err();
    assert!(matches!(err, ServeError::Backpressure { max_pending: 1, .. }), "{err}");
    assert!(err.to_string().contains("backpressure"), "{err}");
    scheduler.tick(&mut pool).unwrap();
    // after the tick drains the queue, the stream can submit again
    let mut out = [0.0f32; 2];
    pool.take_output(a, &mut out).unwrap();
    pool.submit(b, &[0.1; 4], &[0.2; 4], &[1.0, 2.0]).unwrap();
    scheduler.tick(&mut pool).unwrap();
    pool.take_output(b, &mut out).unwrap();
    // stale handle after retire + slot reuse
    pool.retire(a).unwrap();
    let c = pool.admit().unwrap();
    assert_eq!(
        pool.submit(a, &[0.0; 4], &[0.0; 4], &[0.0; 2]).unwrap_err(),
        ServeError::UnknownStream
    );
    assert_eq!(pool.take_output(a, &mut out).unwrap_err(), ServeError::UnknownStream);
    assert!(pool.retire(c).is_ok());
    assert!(pool.retire(b).is_ok());
    assert_eq!(pool.active_streams(), 0);
    assert_eq!(pool.telemetry().rejected_admits(), 1);
    assert_eq!(pool.telemetry().rejected_submits(), 1);
}
