//! Bench §Serve/net — the HTTP/1.1 gateway vs in-process decode.
//!
//! Runs the same closed-loop workload twice — once through the
//! in-process load generator (the serve subsystem's floor) and once
//! through real TCP connections against an in-process [`Server`] — and
//! writes both to `BENCH_serve_net.json` so the protocol overhead
//! (tokens/sec ratio, added per-token latency) is diffable across PRs.
//! Both runs verify bit-exact against independent single-stream
//! decodes; the socket run must also finish with zero 5xx answers
//! (the CI socket-smoke job greps `"verified":true` and
//! `"http_5xx":0`).
//!
//! Knobs (env): MACFORMER_SERVE_STREAMS (16), MACFORMER_SERVE_TOKENS
//! (48), MACFORMER_SERVE_PROMPT (8), MACFORMER_SERVE_D (16),
//! MACFORMER_SERVE_DV (16), MACFORMER_SERVE_FEATURES (32),
//! MACFORMER_SERVE_MIN_BATCH (2), MACFORMER_SERVE_WORKERS (4),
//! MACFORMER_BENCH_KERNEL (exp), MACFORMER_BENCH_BACKEND (host),
//! MACFORMER_THREADS. The chaos MACFORMER_FAULT_* env knobs apply to
//! the socket arm ([`FaultPlan::from_env`]); NaN injection is ignored
//! over the wire (the JSON grammar cannot spell it).
//!
//! Run with: `cargo bench --bench serve_net`
//!
//! [`Server`]: macformer::serve::Server

use std::str::FromStr;

use anyhow::{anyhow, Result};

use macformer::attn::{Backend, Kernel};
use macformer::fastpath;
use macformer::serve::loadgen::{run, LoadConfig};
use macformer::serve::net::{run_socket, NetConfig};
use macformer::serve::obs;
use macformer::serve::{EngineSpec, FaultPlan, ServeConfig, Server};
use macformer::util::json::Value;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn env_parse<T: FromStr>(name: &str, default: T) -> Result<T>
where
    T::Err: std::fmt::Display,
{
    match std::env::var(name) {
        Err(_) => Ok(default),
        Ok(raw) => T::from_str(&raw).map_err(|e| anyhow!("{name}={raw:?}: {e}")),
    }
}

fn main() -> Result<()> {
    macformer::util::logging::init();
    // clean slate so the per-stage breakdown below covers exactly this
    // run (both arms share the process-wide stage histograms)
    obs::reset();
    let streams = env_usize("MACFORMER_SERVE_STREAMS", 16);
    let tokens = env_usize("MACFORMER_SERVE_TOKENS", 48);
    let kernel: Kernel = env_parse("MACFORMER_BENCH_KERNEL", Kernel::Exp)?;
    let backend: Backend = env_parse("MACFORMER_BENCH_BACKEND", Backend::HostFast)?;
    let faults = FaultPlan::from_env();
    let cfg = LoadConfig {
        streams,
        tokens,
        prompt: env_usize("MACFORMER_SERVE_PROMPT", 8),
        head_dim: env_usize("MACFORMER_SERVE_D", 16),
        dv: env_usize("MACFORMER_SERVE_DV", 16),
        num_features: env_usize("MACFORMER_SERVE_FEATURES", 32),
        kernel,
        backend,
        min_batch: env_usize("MACFORMER_SERVE_MIN_BATCH", 2),
        verify: true,
        faults,
        ..LoadConfig::default()
    };
    println!(
        "=== §Serve/net: {streams} streams x {tokens} tokens, kernel {kernel}, \
         backend {backend}, {} threads{} ===",
        fastpath::parallel::num_threads(),
        if faults.is_active() { " [CHAOS PLAN ACTIVE]" } else { "" }
    );

    // --- arm 1: in-process loadgen (the floor the gateway must chase) ---
    // chaos off here: the in-process arm is the clean baseline
    let inproc_cfg = LoadConfig { faults: FaultPlan::none(), ..cfg.clone() };
    let inproc = run(&inproc_cfg)?;
    println!("{}\n", inproc.render());

    // --- arm 2: the same workload over real TCP ---
    let spec = EngineSpec {
        kernel,
        backend,
        head_dim: cfg.head_dim,
        dv: cfg.dv,
        num_features: cfg.num_features,
        seed: cfg.seed,
    };
    let net = NetConfig {
        workers: env_usize("MACFORMER_SERVE_WORKERS", 4),
        ..NetConfig::default()
    };
    let serve_cfg = ServeConfig { min_batch: cfg.min_batch, ..ServeConfig::new(streams, cfg.dv) };
    let server = Server::start(net, spec, serve_cfg, cfg.resilience.clone(), None)?;
    let addr = server.local_addr().to_string();
    let socket = run_socket(&cfg, &addr)?;
    println!("{}\n", socket.render());
    server.shutdown();

    let inproc_p50 = inproc.telemetry.latency_percentile(50.0);
    let inproc_p99 = inproc.telemetry.latency_percentile(99.0);
    let overhead = if socket.tokens_per_sec > 0.0 {
        inproc.tokens_per_sec / socket.tokens_per_sec
    } else {
        f64::INFINITY
    };
    println!(
        "socket {:.0} tok/s vs in-process {:.0} tok/s ({overhead:.2}x); \
         added latency p50 {:+.6}s p99 {:+.6}s",
        socket.tokens_per_sec,
        inproc.tokens_per_sec,
        socket.latency_p50 - inproc_p50,
        socket.latency_p99 - inproc_p99,
    );

    let doc = Value::obj(vec![
        ("streams", Value::num(streams as f64)),
        ("tokens_per_stream", Value::num(tokens as f64)),
        ("kernel", Value::str(kernel.name())),
        ("threads", Value::num(fastpath::parallel::num_threads() as f64)),
        ("simd_supported", Value::Bool(fastpath::simd::supported())),
        ("chaos_active", Value::Bool(faults.is_active())),
        ("inproc_tokens_per_sec", Value::num(inproc.tokens_per_sec)),
        ("socket_tokens_per_sec", Value::num(socket.tokens_per_sec)),
        ("throughput_overhead", Value::num(overhead)),
        ("inproc_latency_p50_s", Value::num(inproc_p50)),
        ("inproc_latency_p99_s", Value::num(inproc_p99)),
        ("socket_latency_p50_s", Value::num(socket.latency_p50)),
        ("socket_latency_p99_s", Value::num(socket.latency_p99)),
        ("added_latency_p50_s", Value::num(socket.latency_p50 - inproc_p50)),
        ("added_latency_p99_s", Value::num(socket.latency_p99 - inproc_p99)),
        // CI socket-smoke greps the three below
        ("verified", Value::Bool(inproc.verified == Some(true) && socket.verified == Some(true))),
        ("http_5xx", Value::num(socket.http_5xx as f64)),
        ("http_429", Value::num(socket.http_429 as f64)),
        ("stream_errors", Value::num(inproc.stream_errors as f64 + socket.stream_errors as f64)),
        ("faulted_streams", Value::num(socket.faulted_streams as f64)),
        ("poisoned_streams", Value::num(socket.poisoned_streams as f64)),
        // per-stage latency breakdown across both arms (the socket arm
        // adds the HTTP stages: accept, head/body parse, SSE writes)
        ("stage_breakdown", obs::stage_breakdown_json()),
        ("inproc", inproc.to_json()),
        ("socket", socket.to_json()),
    ]);
    std::fs::write("BENCH_serve_net.json", doc.to_string())?;
    println!("serve/net reports written to BENCH_serve_net.json");

    // Planned chaos casualties are expected under an active plan;
    // escaped poison, unexpected errors, or any 5xx are never OK.
    let degraded = inproc.verified != Some(true)
        || socket.verified != Some(true)
        || inproc.stream_errors > 0
        || socket.stream_errors > 0
        || socket.poisoned_streams > 0
        || socket.http_5xx > 0;
    if degraded {
        return Err(anyhow!(
            "serve/net degraded: in-process verified {:?} ({} errors), socket verified {:?} \
             ({} errors, {} poisoned, {} x 5xx)",
            inproc.verified,
            inproc.stream_errors,
            socket.verified,
            socket.stream_errors,
            socket.poisoned_streams,
            socket.http_5xx
        ));
    }
    Ok(())
}
