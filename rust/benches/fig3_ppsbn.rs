//! Bench E4 — Fig 3: loss / perplexity / BLEU per epoch for the base
//! Transformer vs Transformer + ppSBN on the synthetic translation task.
//!
//! Knobs: MACFORMER_BENCH_EPOCHS, MACFORMER_BENCH_SPE (steps/epoch).
//!
//! Run with: `cargo bench --bench fig3_ppsbn`

use macformer::config::RunConfig;
use macformer::coordinator::fig3;
use macformer::runtime::Registry;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    macformer::util::logging::init();
    let epochs = env_usize("MACFORMER_BENCH_EPOCHS", 5);
    let spe = env_usize("MACFORMER_BENCH_SPE", 30);
    let cfg = RunConfig {
        seed: 42,
        train_examples: (spe * 32).max(512),
        eval_examples: 96,
        ..RunConfig::default()
    };
    let reg = Registry::open(std::path::Path::new(&cfg.artifacts_dir))?;
    println!("=== E4 / Fig 3: ppSBN ablation ({epochs} epochs x {spe} steps) ===");
    let result = fig3::run(&reg, &cfg, epochs, spe)?;
    println!("{}", fig3::render(&result));
    std::fs::write("bench_fig3.json", fig3::to_json(&result).to_string())?;
    println!("raw curves written to bench_fig3.json");
    Ok(())
}
