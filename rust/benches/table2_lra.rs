//! Bench E5 — Table 2: train time / peak memory / accuracy for the base
//! Transformer, RFA, and the five Macformer kernels on the three LRA
//! tasks, normalized to the base Transformer (paper protocol: one
//! subprocess per cell so RSS peaks do not contaminate).
//!
//! Full-fidelity runs take hours on CPU; defaults here are sized for a
//! meaningful *shape* comparison (who is faster, by what factor). Knobs:
//! MACFORMER_BENCH_STEPS, _TASKS, _VARIANTS, _EXAMPLES.
//!
//! Run with: `cargo bench --bench table2_lra`

use macformer::config::RunConfig;
use macformer::coordinator::sweep;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn env_csv(name: &str, default: &str) -> Vec<String> {
    std::env::var(name)
        .unwrap_or_else(|_| default.to_string())
        .split(',')
        .map(|s| s.to_string())
        .collect()
}

fn main() -> anyhow::Result<()> {
    macformer::util::logging::init();
    let steps = env_usize("MACFORMER_BENCH_STEPS", 10);
    let examples = env_usize("MACFORMER_BENCH_EXAMPLES", 128);
    let tasks = env_csv("MACFORMER_BENCH_TASKS", "lra_text,lra_listops,lra_retrieval");
    let variants_owned = env_csv(
        "MACFORMER_BENCH_VARIANTS",
        "softmax,rfa,mac_exp,mac_inv,mac_trigh,mac_log,mac_sqrt",
    );
    let variants: Vec<&str> = variants_owned.iter().map(|s| s.as_str()).collect();

    // NOTE: the subprocess binary must exist — cargo bench builds it first
    // via the dependency on the bin target? It does not; require release
    // binary built by `make build` and fall back to building here.
    let bin = std::path::Path::new("target/release/macformer");
    if !bin.exists() {
        eprintln!("building release binary for subprocess cells...");
        let ok = std::process::Command::new("cargo")
            .args(["build", "--release", "--offline", "--bin", "macformer"])
            .status()?
            .success();
        anyhow::ensure!(ok, "failed to build macformer binary");
    }

    let cfg = RunConfig {
        steps,
        train_examples: examples,
        eval_examples: 64,
        log_every: 1,
        seed: 42,
        ..RunConfig::default()
    };
    println!(
        "=== E5 / Table 2: {} steps/cell, {} train examples, tasks {tasks:?} ===",
        steps, examples
    );
    let mut tables = Vec::new();
    for task in &tasks {
        tables.push(sweep::run_task_with_binary(&cfg, task, &variants, bin)?);
    }
    println!("{}", sweep::render_table(&tables));
    std::fs::write("bench_table2.json", sweep::to_json(&tables).to_string())?;
    println!("raw cells written to bench_table2.json");
    Ok(())
}
