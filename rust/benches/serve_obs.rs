//! Bench §Serve/obs — the observability overhead gate.
//!
//! Stage-span recording rides the request hot path (tick gather, phi
//! GEMM, state fold, journal, SSE writes), so it must be close to
//! free. This bench runs the in-process serve load generator with span
//! recording disabled, then enabled, on both SIMD dispatch arms, and
//! fails unless the obs-on throughput stays within 5% of obs-off.
//! Results land in `BENCH_serve_obs.json`; the CI metrics-smoke job
//! greps the top-level `"within_5pct"` key.
//!
//! Each (arm, obs) cell is best-of-N wall-clock (default 3) after one
//! untimed warmup, which also pre-registers the span rings and warms
//! the pool so steady state — the regime the 5% claim is about — is
//! what gets timed.
//!
//! Knobs (env): MACFORMER_SERVE_STREAMS (32), MACFORMER_SERVE_TOKENS
//! (64), MACFORMER_SERVE_D (32), MACFORMER_SERVE_DV (32),
//! MACFORMER_SERVE_FEATURES (64), MACFORMER_SERVE_MIN_BATCH (2),
//! MACFORMER_BENCH_KERNEL (exp), MACFORMER_BENCH_BACKEND (host),
//! MACFORMER_OBS_REPEATS (3), MACFORMER_THREADS.
//!
//! Run with: `cargo bench --bench serve_obs`

use std::str::FromStr;

use anyhow::{anyhow, Result};

use macformer::attn::{Backend, Kernel};
use macformer::fastpath;
use macformer::serve::loadgen::{run, LoadConfig};
use macformer::serve::obs;
use macformer::util::json::Value;

/// The gate: obs-on must keep at least this fraction of obs-off
/// throughput on every arm.
const GATE: f64 = 0.95;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn env_parse<T: FromStr>(name: &str, default: T) -> Result<T>
where
    T::Err: std::fmt::Display,
{
    match std::env::var(name) {
        Err(_) => Ok(default),
        Ok(raw) => T::from_str(&raw).map_err(|e| anyhow!("{name}={raw:?}: {e}")),
    }
}

/// Best-of-`repeats` tokens/sec for the current (arm, obs) setting.
fn best_tokens_per_sec(cfg: &LoadConfig, repeats: usize) -> Result<f64> {
    let mut best = 0.0f64;
    for _ in 0..repeats {
        let report = run(cfg)?;
        if report.stream_errors > 0 || report.poisoned_streams > 0 {
            return Err(anyhow!(
                "obs bench load degraded: {} stream errors, {} poisoned",
                report.stream_errors,
                report.poisoned_streams
            ));
        }
        best = best.max(report.tokens_per_sec);
    }
    Ok(best)
}

fn main() -> Result<()> {
    macformer::util::logging::init();
    let streams = env_usize("MACFORMER_SERVE_STREAMS", 32);
    let tokens = env_usize("MACFORMER_SERVE_TOKENS", 64);
    let repeats = env_usize("MACFORMER_OBS_REPEATS", 3).max(1);
    let kernel: Kernel = env_parse("MACFORMER_BENCH_KERNEL", Kernel::Exp)?;
    let backend: Backend = env_parse("MACFORMER_BENCH_BACKEND", Backend::HostFast)?;
    // verification replays every stream single-threaded and would
    // dominate the wall clock; the equivalence suites own correctness
    let cfg = LoadConfig {
        streams,
        tokens,
        head_dim: env_usize("MACFORMER_SERVE_D", 32),
        dv: env_usize("MACFORMER_SERVE_DV", 32),
        num_features: env_usize("MACFORMER_SERVE_FEATURES", 64),
        kernel,
        backend,
        min_batch: env_usize("MACFORMER_SERVE_MIN_BATCH", 2),
        verify: false,
        ..LoadConfig::default()
    };
    println!(
        "=== §Serve/obs overhead gate: {streams} streams x {tokens} tokens, kernel {kernel}, \
         backend {backend}, best of {repeats}, {} threads ===",
        fastpath::parallel::num_threads(),
    );

    let mut arms = Vec::new();
    let mut all_within = true;
    let arm_requests =
        if fastpath::simd::supported() { vec![false, true] } else { vec![false] };
    for want_vector in arm_requests {
        let vector = fastpath::simd::set_active(want_vector);
        let arm = if vector { "simd" } else { "scalar" };

        // untimed warmup: pool allocation, thread-pool spin-up, span
        // ring registration
        obs::set_enabled(true);
        run(&cfg)?;

        obs::set_enabled(false);
        let off = best_tokens_per_sec(&cfg, repeats)?;
        obs::set_enabled(true);
        obs::reset(); // the breakdown below covers only obs-on runs
        let on = best_tokens_per_sec(&cfg, repeats)?;

        let ratio = if off > 0.0 { on / off } else { 0.0 };
        let within = ratio >= GATE;
        all_within &= within;
        println!(
            "{arm:>6}: obs-off {off:>10.0} tok/s, obs-on {on:>10.0} tok/s \
             (ratio {ratio:.3}, gate {GATE}) {}",
            if within { "OK" } else { "FAIL" },
        );
        arms.push(Value::obj(vec![
            ("arm", Value::str(arm)),
            ("obs_off_tokens_per_sec", Value::num(off)),
            ("obs_on_tokens_per_sec", Value::num(on)),
            ("ratio", Value::num(ratio)),
            ("within", Value::Bool(within)),
        ]));
    }
    fastpath::simd::reset();
    obs::set_enabled(true);

    let doc = Value::obj(vec![
        ("streams", Value::num(streams as f64)),
        ("tokens_per_stream", Value::num(tokens as f64)),
        ("kernel", Value::str(kernel.name())),
        ("threads", Value::num(fastpath::parallel::num_threads() as f64)),
        ("simd_supported", Value::Bool(fastpath::simd::supported())),
        ("repeats", Value::num(repeats as f64)),
        ("gate", Value::num(GATE)),
        // CI greps this one key; it only appears here at top level
        ("within_5pct", Value::Bool(all_within)),
        ("arms", Value::Arr(arms)),
        ("stage_breakdown", obs::stage_breakdown_json()),
    ]);
    std::fs::write("BENCH_serve_obs.json", doc.to_string())?;
    println!("obs overhead report written to BENCH_serve_obs.json");

    if !all_within {
        return Err(anyhow!(
            "observability overhead gate failed: obs-on dropped below {GATE} of obs-off \
             (see BENCH_serve_obs.json)"
        ));
    }
    Ok(())
}
