//! Bench E1 — regenerate Table 1 and numerically validate every kernel's
//! Maclaurin expansion against its closed form (the paper's two formula
//! typos are caught by exactly this check; see `attn::Kernel`).
//!
//! Run with: `cargo bench --bench table1_kernels`

use macformer::attn::{degree_distribution, Kernel};

fn main() {
    println!("=== E1 / Table 1: dot-product kernels and Maclaurin coefficients ===\n");
    println!("{:<8}{:<28}{}", "K", "f(x.y)", "a_N (N = 0..6)");
    let forms = [
        (Kernel::Exp, "exp(x.y)"),
        (Kernel::Inv, "1/(1 - x.y)"),
        (Kernel::Log, "1 - log(1 - x.y)"),
        (Kernel::Trigh, "sinh(x.y) + cosh(x.y)"),
        (Kernel::Sqrt, "2 - sqrt(1 - x.y)"),
    ];
    for (k, form) in forms {
        let coeffs: Vec<String> = (0..=6)
            .map(|n| format!("{:.4}", k.coefficient(n).expect("Table-1 kernel")))
            .collect();
        println!("{k:<8}{form:<28}{}", coeffs.join(" "));
    }

    println!("\nvalidation: max rel |closed - series| over t in [-0.5, 0.9]");
    println!("(degree 16 for |t| <= 0.6, 60 near the domain edge — inv/log");
    println!(" converge geometrically in |t|, so the edge needs more terms):");
    let mut all_ok = true;
    for k in Kernel::MACLAURIN {
        let mut worst = 0.0f64;
        let mut i = 0;
        while i <= 28 {
            let t = -0.5 + i as f64 * 0.05;
            let degree = if t.abs() <= 0.6 { 16 } else { 60 };
            let e = k.value(t).expect("Table-1 kernel");
            let s = k.truncated_value(t, degree).expect("Table-1 kernel");
            let rel = (e - s).abs() / e.abs().max(1.0);
            if rel > worst {
                worst = rel;
            }
            i += 1;
        }
        let ok = worst < 0.02;
        all_ok &= ok;
        println!("  {k:<6} {worst:.3e} {}", if ok { "OK" } else { "FAIL" });
    }

    println!("\ndegree law (p = 2): {:?}", degree_distribution(2.0, 8)
        .iter().map(|x| format!("{x:.4}")).collect::<Vec<_>>());
    println!("\nTable 1 regeneration: {}", if all_ok { "PASS" } else { "FAIL" });
    std::process::exit(if all_ok { 0 } else { 1 });
}
