//! Bench §Serve — the closed-loop multi-stream decode load generator.
//!
//! Drives the `serve` subsystem (resilience Supervisor over StreamPool +
//! micro-batching Scheduler) through one scenario per arrival pattern at
//! the configured stream count, with bit-exact verification against
//! independent single-stream decodes enabled, and writes every report
//! (plus the engine telemetry snapshots) to `BENCH_serve.json` so
//! latency/throughput are diffable across PRs. The default scenario
//! sustains 64 concurrent streams on the host tier — the ISSUE's
//! acceptance load.
//!
//! Knobs (env): MACFORMER_SERVE_STREAMS (64), MACFORMER_SERVE_TOKENS
//! (64), MACFORMER_SERVE_PROMPT (0, prompt tokens chunk-prefilled at
//! admission — off by default so throughput stays comparable across
//! PRs), MACFORMER_SERVE_D (32), MACFORMER_SERVE_DV (32),
//! MACFORMER_SERVE_FEATURES (64), MACFORMER_SERVE_MIN_BATCH (2),
//! MACFORMER_SERVE_ARRIVALS (csv of closed|staggered|bursty; default
//! all), MACFORMER_BENCH_KERNEL (exp), MACFORMER_BENCH_BACKEND (host),
//! MACFORMER_THREADS.
//!
//! Chaos knobs (all default off, so the plain bench is a clean run):
//! MACFORMER_FAULT_SEED / _NAN_EVERY / _PANICS / _HIBERNATE_EVERY /
//! _DELAY_EVERY / _DELAY_TICKS pick the deterministic fault plan
//! ([`FaultPlan::from_env`]); MACFORMER_SERVE_IDLE_HIBERNATE /
//! _HIBERNATE_EXPIRE / _OUTPUT_DEADLINE / _SHED_PENDING set the
//! supervisor deadlines/governor; MACFORMER_SERVE_SPILL_DIR spills
//! hibernated records to disk instead of RAM. The CI chaos-smoke job
//! pins a plan and greps the top-level aggregates below.
//!
//! Run with: `cargo bench --bench serve_load`

use std::str::FromStr;

use anyhow::{anyhow, Result};

use macformer::attn::{Backend, Kernel};
use macformer::fastpath;
use macformer::serve::loadgen::{run, Arrival, LoadConfig};
use macformer::serve::obs;
use macformer::serve::{FaultPlan, ResilienceConfig, SpillMode};
use macformer::util::json::Value;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn env_parse<T: FromStr>(name: &str, default: T) -> Result<T>
where
    T::Err: std::fmt::Display,
{
    match std::env::var(name) {
        Err(_) => Ok(default),
        Ok(raw) => T::from_str(&raw).map_err(|e| anyhow!("{name}={raw:?}: {e}")),
    }
}

fn main() -> Result<()> {
    macformer::util::logging::init();
    // clean slate so the per-stage breakdown below covers exactly the
    // scenarios this run drives
    obs::reset();
    let streams = env_usize("MACFORMER_SERVE_STREAMS", 64);
    let tokens = env_usize("MACFORMER_SERVE_TOKENS", 64);
    let kernel: Kernel = env_parse("MACFORMER_BENCH_KERNEL", Kernel::Exp)?;
    let backend: Backend = env_parse("MACFORMER_BENCH_BACKEND", Backend::HostFast)?;
    let arrivals: Vec<Arrival> = match std::env::var("MACFORMER_SERVE_ARRIVALS") {
        Err(_) => Arrival::ALL.to_vec(),
        Ok(raw) => raw
            .split(',')
            .map(|s| Arrival::from_str(s.trim()).map_err(|e| anyhow!("{e}")))
            .collect::<Result<_>>()?,
    };
    let faults = FaultPlan::from_env();
    let resilience = ResilienceConfig {
        idle_hibernate_ticks: env_u64("MACFORMER_SERVE_IDLE_HIBERNATE", 0),
        hibernate_expire_ticks: env_u64("MACFORMER_SERVE_HIBERNATE_EXPIRE", 0),
        output_deadline_ticks: env_u64("MACFORMER_SERVE_OUTPUT_DEADLINE", 0),
        shed_pending: env_usize("MACFORMER_SERVE_SHED_PENDING", 0),
        spill: match std::env::var("MACFORMER_SERVE_SPILL_DIR") {
            Ok(dir) if !dir.is_empty() => SpillMode::Disk(dir.into()),
            _ => SpillMode::Memory,
        },
    };
    let base = LoadConfig {
        streams,
        tokens,
        // default 0 so BENCH_serve.json throughput stays comparable
        // with pre-prefill baselines (prefill wall time lands in the
        // drive loop but prompt tokens are not decode tokens); CI's
        // serve smoke opts in explicitly
        prompt: env_usize("MACFORMER_SERVE_PROMPT", 0),
        head_dim: env_usize("MACFORMER_SERVE_D", 32),
        dv: env_usize("MACFORMER_SERVE_DV", 32),
        num_features: env_usize("MACFORMER_SERVE_FEATURES", 64),
        kernel,
        backend,
        min_batch: env_usize("MACFORMER_SERVE_MIN_BATCH", 2),
        verify: true,
        faults,
        resilience,
        ..LoadConfig::default()
    };
    println!(
        "=== §Serve load: {streams} streams x {tokens} tokens, kernel {kernel}, backend {backend}, {} threads{} ===",
        fastpath::parallel::num_threads(),
        if faults.is_active() { " [CHAOS PLAN ACTIVE]" } else { "" }
    );
    let mut scenarios = Vec::new();
    let mut worst_errors = 0u64;
    let mut all_verified = true;
    let mut faulted_streams = 0u64;
    let mut poisoned_streams = 0u64;
    let mut hibernations = 0u64;
    let mut restores = 0u64;
    for arrival in arrivals {
        let report = run(&LoadConfig { arrival, ..base.clone() })?;
        println!("{}\n", report.render());
        worst_errors = worst_errors.max(report.stream_errors);
        all_verified &= report.verified == Some(true);
        faulted_streams += report.faulted_streams;
        poisoned_streams += report.poisoned_streams;
        hibernations += report.telemetry.hibernations();
        restores += report.telemetry.restores();
        scenarios.push(report.to_json());
    }
    let doc = Value::obj(vec![
        ("streams", Value::num(streams as f64)),
        ("tokens_per_stream", Value::num(tokens as f64)),
        ("kernel", Value::str(kernel.name())),
        (
            "threads",
            Value::num(fastpath::parallel::num_threads() as f64),
        ),
        ("simd_supported", Value::Bool(fastpath::simd::supported())),
        ("chaos_active", Value::Bool(faults.is_active())),
        ("all_verified", Value::Bool(all_verified)),
        ("max_stream_errors", Value::num(worst_errors as f64)),
        // aggregates across scenarios, grepped by the CI chaos gate
        ("faulted_streams", Value::num(faulted_streams as f64)),
        ("poisoned_streams", Value::num(poisoned_streams as f64)),
        ("hibernations", Value::num(hibernations as f64)),
        ("restores", Value::num(restores as f64)),
        // per-stage latency breakdown (tick gather / phi GEMM / state
        // fold / journal ...) from the observability stage histograms
        ("stage_breakdown", obs::stage_breakdown_json()),
        ("scenarios", Value::Arr(scenarios)),
    ]);
    std::fs::write("BENCH_serve.json", doc.to_string())?;
    println!("serve load reports written to BENCH_serve.json");
    // Planned chaos casualties are expected under an active plan;
    // escaped poison or unexpected stream errors are never OK.
    if !all_verified || worst_errors > 0 || poisoned_streams > 0 {
        return Err(anyhow!(
            "serve load degraded: verified {all_verified}, max stream errors {worst_errors}, \
             {poisoned_streams} poisoned streams"
        ));
    }
    Ok(())
}
