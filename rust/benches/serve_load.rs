//! Bench §Serve — the closed-loop multi-stream decode load generator.
//!
//! Drives the `serve` subsystem (StreamPool + micro-batching Scheduler)
//! through one scenario per arrival pattern at the configured stream
//! count, with bit-exact verification against independent single-stream
//! decodes enabled, and writes every report (plus the engine telemetry
//! snapshots) to `BENCH_serve.json` so latency/throughput are diffable
//! across PRs. The default scenario sustains 64 concurrent streams on
//! the host tier — the ISSUE's acceptance load.
//!
//! Knobs (env): MACFORMER_SERVE_STREAMS (64), MACFORMER_SERVE_TOKENS
//! (64), MACFORMER_SERVE_PROMPT (0, prompt tokens chunk-prefilled at
//! admission — off by default so throughput stays comparable across
//! PRs), MACFORMER_SERVE_D (32), MACFORMER_SERVE_DV (32),
//! MACFORMER_SERVE_FEATURES (64), MACFORMER_SERVE_MIN_BATCH (2),
//! MACFORMER_SERVE_ARRIVALS (csv of closed|staggered|bursty; default
//! all), MACFORMER_BENCH_KERNEL (exp), MACFORMER_BENCH_BACKEND (host),
//! MACFORMER_THREADS.
//!
//! Run with: `cargo bench --bench serve_load`

use std::str::FromStr;

use anyhow::{anyhow, Result};

use macformer::attn::{Backend, Kernel};
use macformer::fastpath;
use macformer::serve::loadgen::{run, Arrival, LoadConfig};
use macformer::util::json::Value;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn env_parse<T: FromStr>(name: &str, default: T) -> Result<T>
where
    T::Err: std::fmt::Display,
{
    match std::env::var(name) {
        Err(_) => Ok(default),
        Ok(raw) => T::from_str(&raw).map_err(|e| anyhow!("{name}={raw:?}: {e}")),
    }
}

fn main() -> Result<()> {
    macformer::util::logging::init();
    let streams = env_usize("MACFORMER_SERVE_STREAMS", 64);
    let tokens = env_usize("MACFORMER_SERVE_TOKENS", 64);
    let kernel: Kernel = env_parse("MACFORMER_BENCH_KERNEL", Kernel::Exp)?;
    let backend: Backend = env_parse("MACFORMER_BENCH_BACKEND", Backend::HostFast)?;
    let arrivals: Vec<Arrival> = match std::env::var("MACFORMER_SERVE_ARRIVALS") {
        Err(_) => Arrival::ALL.to_vec(),
        Ok(raw) => raw
            .split(',')
            .map(|s| Arrival::from_str(s.trim()).map_err(|e| anyhow!("{e}")))
            .collect::<Result<_>>()?,
    };
    let base = LoadConfig {
        streams,
        tokens,
        // default 0 so BENCH_serve.json throughput stays comparable
        // with pre-prefill baselines (prefill wall time lands in the
        // drive loop but prompt tokens are not decode tokens); CI's
        // serve smoke opts in explicitly
        prompt: env_usize("MACFORMER_SERVE_PROMPT", 0),
        head_dim: env_usize("MACFORMER_SERVE_D", 32),
        dv: env_usize("MACFORMER_SERVE_DV", 32),
        num_features: env_usize("MACFORMER_SERVE_FEATURES", 64),
        kernel,
        backend,
        min_batch: env_usize("MACFORMER_SERVE_MIN_BATCH", 2),
        verify: true,
        ..LoadConfig::default()
    };
    println!(
        "=== §Serve load: {streams} streams x {tokens} tokens, kernel {kernel}, backend {backend}, {} threads ===",
        fastpath::parallel::num_threads()
    );
    let mut scenarios = Vec::new();
    let mut worst_errors = 0u64;
    let mut all_verified = true;
    for arrival in arrivals {
        let report = run(&LoadConfig { arrival, ..base.clone() })?;
        println!("{}\n", report.render());
        worst_errors = worst_errors.max(report.stream_errors);
        all_verified &= report.verified == Some(true);
        scenarios.push(report.to_json());
    }
    let doc = Value::obj(vec![
        ("streams", Value::num(streams as f64)),
        ("tokens_per_stream", Value::num(tokens as f64)),
        ("kernel", Value::str(kernel.name())),
        (
            "threads",
            Value::num(fastpath::parallel::num_threads() as f64),
        ),
        ("simd_supported", Value::Bool(fastpath::simd::supported())),
        ("all_verified", Value::Bool(all_verified)),
        ("max_stream_errors", Value::num(worst_errors as f64)),
        ("scenarios", Value::Arr(scenarios)),
    ]);
    std::fs::write("BENCH_serve.json", doc.to_string())?;
    println!("serve load reports written to BENCH_serve.json");
    if !all_verified || worst_errors > 0 {
        return Err(anyhow!(
            "serve load degraded: verified {all_verified}, max stream errors {worst_errors}"
        ));
    }
    Ok(())
}
