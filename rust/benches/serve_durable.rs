//! Bench §Serve/durable — the write-ahead journal's cost over the
//! gateway.
//!
//! Runs the same closed-loop socket workload twice against an
//! in-process [`Server`] — once with durability off (the floor) and
//! once journaling every accepted op to a scratch data dir with the
//! production group-commit cadence — and writes both to
//! `BENCH_serve_durable.json`. The acceptance bar for the durability
//! layer is `within_10pct`: journal-on throughput must stay within 10%
//! of the journal-off floor. Both arms must verify bit-exact with zero
//! 5xx; a perf miss is reported in the JSON, never a bench failure
//! (CI timing noise must not mask a correctness signal).
//!
//! Knobs (env): the MACFORMER_SERVE_* shape knobs from `serve_net`,
//! plus MACFORMER_SERVE_SYNC_EVERY (32) and MACFORMER_SERVE_CKPT_EVERY
//! (1024).
//!
//! Run with: `cargo bench --bench serve_durable`
//!
//! [`Server`]: macformer::serve::Server

use std::path::Path;
use std::str::FromStr;

use anyhow::{anyhow, Result};

use macformer::attn::{Backend, Kernel};
use macformer::fastpath;
use macformer::serve::loadgen::LoadConfig;
use macformer::serve::net::{run_socket, NetConfig};
use macformer::serve::{DurabilityConfig, EngineSpec, FaultPlan, ServeConfig, Server};
use macformer::util::json::Value;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn env_parse<T: FromStr>(name: &str, default: T) -> Result<T>
where
    T::Err: std::fmt::Display,
{
    match std::env::var(name) {
        Err(_) => Ok(default),
        Ok(raw) => T::from_str(&raw).map_err(|e| anyhow!("{name}={raw:?}: {e}")),
    }
}

fn server_for(cfg: &LoadConfig, durability: Option<DurabilityConfig>) -> Result<Server> {
    let spec = EngineSpec {
        kernel: cfg.kernel,
        backend: cfg.backend,
        head_dim: cfg.head_dim,
        dv: cfg.dv,
        num_features: cfg.num_features,
        seed: cfg.seed,
    };
    let net = NetConfig {
        workers: env_usize("MACFORMER_SERVE_WORKERS", 4),
        ..NetConfig::default()
    };
    let serve = ServeConfig { min_batch: cfg.min_batch, ..ServeConfig::new(cfg.streams, cfg.dv) };
    Server::start(net, spec, serve, cfg.resilience.clone(), durability)
}

/// Total bytes left in the data dir (journal epochs + checkpoint).
fn dir_bytes(dir: &Path) -> u64 {
    let Ok(entries) = std::fs::read_dir(dir) else { return 0 };
    entries.flatten().filter_map(|e| e.metadata().ok()).map(|m| m.len()).sum()
}

fn main() -> Result<()> {
    macformer::util::logging::init();
    let streams = env_usize("MACFORMER_SERVE_STREAMS", 16);
    let tokens = env_usize("MACFORMER_SERVE_TOKENS", 48);
    let kernel: Kernel = env_parse("MACFORMER_BENCH_KERNEL", Kernel::Exp)?;
    let backend: Backend = env_parse("MACFORMER_BENCH_BACKEND", Backend::HostFast)?;
    let sync_every = env_u64("MACFORMER_SERVE_SYNC_EVERY", 32);
    let ckpt_every = env_u64("MACFORMER_SERVE_CKPT_EVERY", 1024);
    let cfg = LoadConfig {
        streams,
        tokens,
        prompt: env_usize("MACFORMER_SERVE_PROMPT", 8),
        head_dim: env_usize("MACFORMER_SERVE_D", 16),
        dv: env_usize("MACFORMER_SERVE_DV", 16),
        num_features: env_usize("MACFORMER_SERVE_FEATURES", 32),
        kernel,
        backend,
        min_batch: env_usize("MACFORMER_SERVE_MIN_BATCH", 2),
        verify: true,
        faults: FaultPlan::none(),
        ..LoadConfig::default()
    };
    println!(
        "=== §Serve/durable: {streams} streams x {tokens} tokens, kernel {kernel}, \
         backend {backend}, {} threads, sync every {sync_every} tick(s) ===",
        fastpath::parallel::num_threads()
    );

    // --- arm 1: journal off (the floor the durable arm must chase) ---
    let server = server_for(&cfg, None)?;
    let addr = server.local_addr().to_string();
    let floor = run_socket(&cfg, &addr)?;
    println!("{}\n", floor.render());
    server.shutdown();

    // --- arm 2: every accepted op journaled with group commit ---
    let dir = std::env::temp_dir().join(format!("macformer_bench_durable_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let durability = DurabilityConfig {
        sync_every_ticks: sync_every,
        checkpoint_every_ticks: ckpt_every,
        ..DurabilityConfig::new(&dir)
    };
    let server = server_for(&cfg, Some(durability))?;
    let addr = server.local_addr().to_string();
    let durable = run_socket(&cfg, &addr)?;
    println!("{}\n", durable.render());
    server.shutdown();
    let journal_bytes = dir_bytes(&dir);
    let _ = std::fs::remove_dir_all(&dir);

    let overhead = if durable.tokens_per_sec > 0.0 {
        floor.tokens_per_sec / durable.tokens_per_sec
    } else {
        f64::INFINITY
    };
    let within_10pct = durable.tokens_per_sec >= 0.9 * floor.tokens_per_sec;
    println!(
        "journal-on {:.0} tok/s vs journal-off {:.0} tok/s ({overhead:.3}x, within 10%: \
         {within_10pct}); {journal_bytes} journal+checkpoint bytes at shutdown",
        durable.tokens_per_sec,
        floor.tokens_per_sec,
    );

    let doc = Value::obj(vec![
        ("streams", Value::num(streams as f64)),
        ("tokens_per_stream", Value::num(tokens as f64)),
        ("kernel", Value::str(kernel.name())),
        ("threads", Value::num(fastpath::parallel::num_threads() as f64)),
        ("simd_supported", Value::Bool(fastpath::simd::supported())),
        ("sync_every_ticks", Value::num(sync_every as f64)),
        ("checkpoint_every_ticks", Value::num(ckpt_every as f64)),
        ("floor_tokens_per_sec", Value::num(floor.tokens_per_sec)),
        ("durable_tokens_per_sec", Value::num(durable.tokens_per_sec)),
        ("journal_overhead", Value::num(overhead)),
        ("journal_bytes", Value::num(journal_bytes as f64)),
        // CI greps the three below
        ("within_10pct", Value::Bool(within_10pct)),
        ("verified", Value::Bool(floor.verified == Some(true) && durable.verified == Some(true))),
        ("http_5xx", Value::num((floor.http_5xx + durable.http_5xx) as f64)),
        ("stream_errors", Value::num((floor.stream_errors + durable.stream_errors) as f64)),
        ("floor", floor.to_json()),
        ("durable", durable.to_json()),
    ]);
    std::fs::write("BENCH_serve_durable.json", doc.to_string())?;
    println!("serve/durable report written to BENCH_serve_durable.json");

    let degraded = floor.verified != Some(true)
        || durable.verified != Some(true)
        || floor.stream_errors > 0
        || durable.stream_errors > 0
        || floor.http_5xx > 0
        || durable.http_5xx > 0;
    if degraded {
        return Err(anyhow!(
            "serve/durable degraded: floor verified {:?} ({} errors, {} x 5xx), durable \
             verified {:?} ({} errors, {} x 5xx)",
            floor.verified,
            floor.stream_errors,
            floor.http_5xx,
            durable.verified,
            durable.stream_errors,
            durable.http_5xx
        ));
    }
    Ok(())
}
