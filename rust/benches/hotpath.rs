//! Bench §Perf — the L3 hot path: per-step cost breakdown of the training
//! loop (batch staging, host->device upload, execute, tuple round-trip)
//! on the lra_text.mac_exp cell. This is the harness behind the §Perf
//! before/after numbers in EXPERIMENTS.md.
//!
//! Run with: `cargo bench --bench hotpath`

use std::time::Instant;

use macformer::config::RunConfig;
use macformer::coordinator::{TaskData, Trainer};
use macformer::metrics::Timing;
use macformer::runtime::{DeviceState, Executable, Registry};

fn main() -> anyhow::Result<()> {
    macformer::util::logging::init();
    let steps: usize = std::env::var("MACFORMER_BENCH_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(12);
    let cfg = RunConfig {
        task: "lra_text".into(),
        variant: "mac_exp".into(),
        train_examples: 128,
        eval_examples: 32,
        steps,
        log_every: 1,
        seed: 7,
        ..RunConfig::default()
    };
    let reg = Registry::open(std::path::Path::new(&cfg.artifacts_dir))?;
    println!("=== §Perf hot path: {} ({} steps) ===", cfg.family(), steps);
    let mut tr = Trainer::build(cfg.clone(), &reg)?;

    // timed phases per step
    let mut stage_t = Timing::default();
    let mut step_t = Timing::default();
    let mut loss_t = Timing::default();
    let data = TaskData::build(&cfg.task, cfg.seed, cfg.train_examples, tr.info.seq_len, 24)?;
    for s in 0..steps {
        let idx: Vec<usize> = (0..tr.info.batch).map(|i| (s * tr.info.batch + i) % data.len()).collect();
        let t0 = Instant::now();
        let batch = data.stage(&idx, tr.info.seq_len);
        stage_t.push(t0.elapsed().as_secs_f64());
        let t1 = Instant::now();
        let loss_buf = tr.step_with(&batch)?;
        step_t.push(t1.elapsed().as_secs_f64());
        let t2 = Instant::now();
        let _ = DeviceState::loss_value(&loss_buf)?;
        loss_t.push(t2.elapsed().as_secs_f64());
    }
    println!(
        "batch staging : mean {:>9.4}s  min {:>9.4}s",
        stage_t.mean(),
        stage_t.min()
    );
    println!(
        "train step    : mean {:>9.4}s  min {:>9.4}s (upload + execute + tuple round-trip)",
        step_t.mean(),
        step_t.min()
    );
    println!(
        "loss fetch    : mean {:>9.4}s  min {:>9.4}s",
        loss_t.mean(),
        loss_t.min()
    );

    // isolate the tuple round-trip: run an eval-style fetch-only pass
    let total = step_t.mean() + stage_t.mean() + loss_t.mean();
    println!("total/step    : {total:>9.4}s");
    Ok(())
}
