//! Bench §Perf — the hot paths, in two tiers:
//!
//! 1. **Host compute path** (always runs): one `attn` spec built twice —
//!    `Backend::Reference` (scalar per-problem oracle, single thread)
//!    and `Backend::HostFast` (degree-grouped `FlatRmfMap` GEMMs +
//!    persistent-pool batched linear attention) — both driven through
//!    the `AttentionBackend` dispatch at the Fig-4 stress shape n=2048,
//!    D=128. This is the fast-vs-oracle speedup tracked across PRs.
//!    The host-fast session is then re-timed with the SIMD dispatch
//!    pinned to each arm (`fastpath::simd::set_active`), producing the
//!    `speedup_simd_vs_scalar` field (target >= 2x on AVX2 hosts;
//!    reported as 1.0 with `"simd_supported": false` elsewhere).
//! 2. **Training loop** (needs `make artifacts` + a PJRT runtime):
//!    per-step cost breakdown on the lra_text.mac_exp cell — batch
//!    staging, train step (upload + execute + tuple round-trip), loss
//!    fetch, and a fetch-only pass (full state download, no re-upload)
//!    that isolates the device->host half of the tuple round-trip.
//!
//! Every phase's mean/min seconds is written to `BENCH_hotpath.json` so
//! the perf trajectory is diffable across PRs.
//!
//! Knobs: MACFORMER_BENCH_STEPS, _N, _FEATURES1, _GROUPS, _REPEATS,
//! MACFORMER_THREADS.
//!
//! Run with: `cargo bench --bench hotpath`

use std::time::Instant;

use macformer::attn::{AttentionSpec, Backend, Kernel};
use macformer::config::RunConfig;
use macformer::coordinator::{microbench, TaskData, Trainer};
use macformer::fastpath;
use macformer::metrics::Timing;
use macformer::runtime::{DeviceState, Registry};
use macformer::tensor::Tensor;
use macformer::util::json::Value;
use macformer::util::rng::Rng;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn phase_json(t: &Timing) -> Value {
    Value::obj(vec![
        ("mean", Value::num(t.mean())),
        ("min", Value::num(if t.count() == 0 { 0.0 } else { t.min() })),
    ])
}

fn print_phase(name: &str, t: &Timing) {
    println!("{name:<22}: mean {:>9.4}s  min {:>9.4}s", t.mean(), t.min());
}

/// Host tier: one RMFA_exp spec built on the reference and host-fast
/// backends, both driven through the `attn` session dispatch on one
/// batched problem set and timed min-over-`repeats` via the shared
/// `microbench::time_forward` helper (no warm-up bias between the two).
/// Returns the JSON report block.
fn host_phases() -> anyhow::Result<Value> {
    let n = env_usize("MACFORMER_BENCH_N", 2048);
    let feat = env_usize("MACFORMER_BENCH_FEATURES1", 128);
    let d = 64;
    let groups = env_usize("MACFORMER_BENCH_GROUPS", 16);
    let repeats = env_usize("MACFORMER_BENCH_REPEATS", 3);
    println!(
        "--- host compute path: n={n} D={feat} d={d} x {groups} problems, {} threads ---",
        fastpath::parallel::num_threads()
    );
    let mut rng = Rng::new(7);
    let q = Tensor::randn(&mut rng, &[groups, n, d], 0.5);
    let k = Tensor::randn(&mut rng, &[groups, n, d], 0.5);
    let v = Tensor::randn(&mut rng, &[groups, n, d], 1.0);
    // one spec, two tiers — the same map draw (seed) on both
    let spec = AttentionSpec::new(Kernel::Exp)
        .head_dim(d)
        .num_features(feat)
        .eps(1e-6)
        .seed(0xFEA7);
    let reference = spec.clone().backend(Backend::Reference).build()?;
    let fast = spec.backend(Backend::HostFast).build()?;

    let (_ref_out, ref_t) = microbench::time_forward(&reference, &q, &k, &v, repeats)?;
    let (_out, fast_t) = microbench::time_forward(&fast, &q, &k, &v, repeats)?;

    let speedup = ref_t.min() / fast_t.min();
    print_phase("rmfa reference", &ref_t);
    print_phase("rmfa fastpath", &fast_t);
    println!("fastpath speedup      : x{speedup:.2} (reference min / fastpath min)");

    // SIMD arm vs scalar arm of the same host-fast session: pin the
    // dispatch to each arm in turn, then restore the env/CPU default.
    let simd_supported = fastpath::simd::supported();
    fastpath::simd::set_active(false);
    let (_s, scalar_t) = microbench::time_forward(&fast, &q, &k, &v, repeats)?;
    let simd_on = fastpath::simd::set_active(true);
    let simd_t = if simd_on {
        let (_v, t) = microbench::time_forward(&fast, &q, &k, &v, repeats)?;
        t
    } else {
        scalar_t.clone()
    };
    fastpath::simd::reset();
    let speedup_simd = if simd_on { scalar_t.min() / simd_t.min() } else { 1.0 };
    print_phase("rmfa fastpath scalar", &scalar_t);
    if simd_on {
        print_phase("rmfa fastpath simd", &simd_t);
        println!("simd speedup          : x{speedup_simd:.2} (scalar min / simd min)");
    } else {
        println!("simd speedup          : skipped (no AVX2+FMA on this host)");
    }

    Ok(Value::obj(vec![
        ("n", Value::num(n as f64)),
        ("D", Value::num(feat as f64)),
        ("d", Value::num(d as f64)),
        ("groups", Value::num(groups as f64)),
        (
            "threads",
            Value::num(fastpath::parallel::num_threads() as f64),
        ),
        ("simd_supported", Value::Bool(simd_supported)),
        (
            "phases",
            Value::obj(vec![
                ("rmfa_reference", phase_json(&ref_t)),
                ("rmfa_fastpath", phase_json(&fast_t)),
                ("rmfa_fastpath_scalar", phase_json(&scalar_t)),
                ("rmfa_fastpath_simd", phase_json(&simd_t)),
            ]),
        ),
        ("speedup_fastpath_vs_reference", Value::num(speedup)),
        ("speedup_simd_vs_scalar", Value::num(speedup_simd)),
    ]))
}

/// Trainer tier: per-step phase breakdown over PJRT. Errors (no
/// artifacts / no PJRT runtime) are reported by the caller as a skip.
fn trainer_phases(steps: usize) -> anyhow::Result<Value> {
    let cfg = RunConfig {
        task: "lra_text".into(),
        variant: "mac_exp".into(),
        train_examples: 128,
        eval_examples: 32,
        steps,
        log_every: 1,
        seed: 7,
        ..RunConfig::default()
    };
    let reg = Registry::open(std::path::Path::new(&cfg.artifacts_dir))?;
    println!("--- training loop: {} ({} steps) ---", cfg.family(), steps);
    let mut tr = Trainer::build(cfg.clone(), &reg)?;

    let mut stage_t = Timing::default();
    let mut step_t = Timing::default();
    let mut loss_t = Timing::default();
    let mut fetch_t = Timing::default();
    let data = TaskData::build(&cfg.task, cfg.seed, cfg.train_examples, tr.info.seq_len, 24)?;
    for s in 0..steps {
        let idx: Vec<usize> = (0..tr.info.batch)
            .map(|i| (s * tr.info.batch + i) % data.len())
            .collect();
        let t0 = Instant::now();
        let batch = data.stage(&idx, tr.info.seq_len);
        stage_t.push(t0.elapsed().as_secs_f64());
        let t1 = Instant::now();
        let loss_buf = tr.step_with(&batch)?;
        step_t.push(t1.elapsed().as_secs_f64());
        let t2 = Instant::now();
        let _ = DeviceState::loss_value(&loss_buf)?;
        loss_t.push(t2.elapsed().as_secs_f64());
        // fetch-only pass: download the full device state WITHOUT
        // re-uploading — isolates the device->host half of the tuple
        // round-trip that the train step pays inside
        // run_buffers_untupled.
        let t3 = Instant::now();
        let _ = tr.state.download()?;
        fetch_t.push(t3.elapsed().as_secs_f64());
    }
    print_phase("batch staging", &stage_t);
    println!(
        "{:<22}: mean {:>9.4}s  min {:>9.4}s (upload + execute + tuple round-trip)",
        "train step",
        step_t.mean(),
        step_t.min()
    );
    print_phase("loss fetch", &loss_t);
    println!(
        "{:<22}: mean {:>9.4}s  min {:>9.4}s (state download, no re-upload)",
        "fetch-only pass",
        fetch_t.mean(),
        fetch_t.min()
    );
    let total = stage_t.mean() + step_t.mean() + loss_t.mean();
    println!("total/step            : {total:>9.4}s (excluding the fetch-only probe)");
    Ok(Value::obj(vec![
        ("family", Value::str(cfg.family())),
        ("steps", Value::num(steps as f64)),
        (
            "phases",
            Value::obj(vec![
                ("batch_staging", phase_json(&stage_t)),
                ("train_step", phase_json(&step_t)),
                ("loss_fetch", phase_json(&loss_t)),
                ("state_fetch_only", phase_json(&fetch_t)),
            ]),
        ),
    ]))
}

fn main() -> anyhow::Result<()> {
    macformer::util::logging::init();
    let steps = env_usize("MACFORMER_BENCH_STEPS", 12);
    println!("=== §Perf hot path ===");
    let host = host_phases()?;
    let trainer = match trainer_phases(steps) {
        Ok(v) => v,
        Err(e) => {
            println!("training-loop tier skipped: {e}");
            Value::Null
        }
    };
    let report = Value::obj(vec![("host", host), ("trainer", trainer)]);
    std::fs::write("BENCH_hotpath.json", report.to_string())?;
    println!("per-phase timings written to BENCH_hotpath.json");
    Ok(())
}
