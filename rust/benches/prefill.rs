//! Bench §Prefill — chunkwise-parallel causal prefill vs the
//! sequential `(S, z)` fold.
//!
//! One realistic RMFA_exp phi draw; for each sequence length the causal
//! prefill runs once as the token-by-token fold (chunk width 1 — the
//! path streaming decode takes) and once per chunk width through the
//! chunked GEMM kernel, on both SIMD dispatch arms (the scalar arm is
//! always timed; the AVX2+FMA arm when the host supports it). Every
//! (length, chunk) cell is verified: outputs within 1e-5 of the
//! sequential fold and the reference oracle, and the final `(S, z)`
//! state **bit-identical** to the fold's — the prefill-then-decode
//! bit-compat criterion.
//!
//! Everything is written to `BENCH_prefill.json`: per-cell timings and
//! speedups plus `speedup_max_n_simd` / `speedup_max_n_scalar` (best
//! chunked speedup at the largest length; the PR's acceptance target is
//! >= 3x at n = 4096 on the SIMD arm) and a global `verified` flag.
//!
//! Knobs (env): MACFORMER_PREFILL_NS ("512,2048,4096"),
//! MACFORMER_PREFILL_CHUNKS ("16,64,256"), MACFORMER_PREFILL_FEATURES
//! (128), MACFORMER_PREFILL_DV (64), MACFORMER_PREFILL_D (32),
//! MACFORMER_BENCH_REPEATS (3).
//!
//! Run with: `cargo bench --bench prefill`

use std::time::Instant;

use macformer::attn::Kernel;
use macformer::fastpath;
use macformer::fastpath::attention::causal_prefill_fold_into;
use macformer::fastpath::FlatRmfMap;
use macformer::metrics::Timing;
use macformer::reference::{attention as oracle, rmf::RmfMap};
use macformer::tensor::Tensor;
use macformer::util::json::Value;
use macformer::util::rng::Rng;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn env_csv(name: &str, default: &[usize]) -> Vec<usize> {
    match std::env::var(name) {
        Err(_) => default.to_vec(),
        Ok(raw) => raw
            .split(',')
            .filter_map(|s| s.trim().parse::<usize>().ok())
            .filter(|&x| x > 0)
            .collect(),
    }
}

struct Cell {
    arm: &'static str,
    n: usize,
    chunk: usize,
    seq_s: f64,
    chunked_s: f64,
    speedup: f64,
    diff_vs_fold: f64,
    diff_vs_oracle: f64,
    state_bit_identical: bool,
}

impl Cell {
    fn to_json(&self) -> Value {
        Value::obj(vec![
            ("arm", Value::str(self.arm)),
            ("n", Value::num(self.n as f64)),
            ("chunk", Value::num(self.chunk as f64)),
            ("sequential_s", Value::num(self.seq_s)),
            ("chunked_s", Value::num(self.chunked_s)),
            ("speedup", Value::num(self.speedup)),
            ("max_scaled_diff_vs_fold", Value::num(self.diff_vs_fold)),
            ("max_scaled_diff_vs_oracle", Value::num(self.diff_vs_oracle)),
            ("state_bit_identical", Value::Bool(self.state_bit_identical)),
        ])
    }
}

/// Time `causal_prefill_fold_into` at one chunk width: fresh state per
/// repeat, min-of-repeats seconds.
#[allow(clippy::too_many_arguments)]
fn time_fold(
    phi_q: &[f32],
    phi_k: &[f32],
    v: &[f32],
    n: usize,
    feat: usize,
    dv: usize,
    chunk: usize,
    repeats: usize,
    s: &mut [f32],
    z: &mut [f32],
    out: &mut [f32],
) -> f64 {
    let mut t = Timing::default();
    for _ in 0..repeats {
        s.fill(0.0);
        z.fill(0.0);
        let t0 = Instant::now();
        causal_prefill_fold_into(phi_q, phi_k, v, n, feat, dv, chunk, 1e-6, s, z, out);
        t.push(t0.elapsed().as_secs_f64());
    }
    t.min()
}

/// True bitwise equality (`to_bits`), not float `==` — `-0.0 == 0.0`
/// must not mask a state that is not actually bit-identical.
fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Max |a - b| scaled by max(1, |b|) per element — the chunked
/// equivalence contract's magnitude-aware 1e-5 comparison.
fn max_scaled_diff(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| ((x - y).abs() / y.abs().max(1.0)) as f64)
        .fold(0.0, f64::max)
}

/// Run the full (n, chunk) grid on the currently pinned dispatch arm.
#[allow(clippy::too_many_arguments)]
fn run_arm(
    arm: &'static str,
    lengths: &[usize],
    chunks: &[usize],
    d: usize,
    feat: usize,
    dv: usize,
    repeats: usize,
    cells: &mut Vec<Cell>,
) {
    // phi is drawn under the pinned arm so chunked and sequential see
    // identical feature rows (the fold comparison is arm-internal)
    let max_n = lengths.iter().copied().max().unwrap_or(0);
    let mut rng = Rng::new(0x9E7F);
    let map = RmfMap::sample(&mut rng, Kernel::Exp, feat, d, 2.0, 8);
    let flat = FlatRmfMap::from(&map);
    let scale = 1.0 / (d as f32).sqrt().sqrt();
    let q = Tensor::randn(&mut rng, &[max_n, d], 0.5).scale(scale);
    let k = Tensor::randn(&mut rng, &[max_n, d], 0.5).scale(scale);
    let v = Tensor::randn(&mut rng, &[max_n, dv], 1.0);
    let phi_q = flat.apply(&q);
    let phi_k = flat.apply(&k);

    let mut s = vec![0.0f32; feat * dv];
    let mut z = vec![0.0f32; feat];
    let mut s_seq = vec![0.0f32; feat * dv];
    let mut z_seq = vec![0.0f32; feat];
    for &n in lengths {
        let pq = &phi_q.data[..n * feat];
        let pk = &phi_k.data[..n * feat];
        let vn = &v.data[..n * dv];
        let mut out_seq = vec![0.0f32; n * dv];
        let seq_s =
            time_fold(pq, pk, vn, n, feat, dv, 1, repeats, &mut s_seq, &mut z_seq, &mut out_seq);
        // the oracle recomputes the same causal contraction scalar-ly
        let pq_t = Tensor::from_vec(&[n, feat], pq.to_vec());
        let pk_t = Tensor::from_vec(&[n, feat], pk.to_vec());
        let vn_t = Tensor::from_vec(&[n, dv], vn.to_vec());
        let ora = oracle::linear_attention(&pq_t, &pk_t, &vn_t, true, 1e-6);
        let mut out = vec![0.0f32; n * dv];
        for &chunk in chunks {
            // steer the process-wide width too (the in-process sweep
            // API every env-driven causal path reads), then time the
            // kernel at the clamped width it returns
            let chunk = macformer::fastpath::attention::set_causal_chunk(chunk);
            let chunked_s =
                time_fold(pq, pk, vn, n, feat, dv, chunk, repeats, &mut s, &mut z, &mut out);
            let cell = Cell {
                arm,
                n,
                chunk,
                seq_s,
                chunked_s,
                speedup: if chunked_s > 0.0 { seq_s / chunked_s } else { 0.0 },
                diff_vs_fold: max_scaled_diff(&out, &out_seq),
                diff_vs_oracle: max_scaled_diff(&out, &ora.data),
                state_bit_identical: bits_eq(&s, &s_seq) && bits_eq(&z, &z_seq),
            };
            println!(
                "[{arm:>6}] n={n:>5} chunk={chunk:>4}: seq {:.4}s  chunked {:.4}s  \
                 x{:.2}  |fold diff| {:.2e}  |oracle diff| {:.2e}  state {}",
                cell.seq_s,
                cell.chunked_s,
                cell.speedup,
                cell.diff_vs_fold,
                cell.diff_vs_oracle,
                if cell.state_bit_identical { "bit-identical" } else { "DRIFTED" },
            );
            cells.push(cell);
        }
    }
}

fn main() -> anyhow::Result<()> {
    macformer::util::logging::init();
    let lengths = env_csv("MACFORMER_PREFILL_NS", &[512, 2048, 4096]);
    let chunks = env_csv("MACFORMER_PREFILL_CHUNKS", &[16, 64, 256]);
    let d = env_usize("MACFORMER_PREFILL_D", 32);
    let feat = env_usize("MACFORMER_PREFILL_FEATURES", 128);
    let dv = env_usize("MACFORMER_PREFILL_DV", 64);
    let repeats = env_usize("MACFORMER_BENCH_REPEATS", 3).max(1);
    let simd_supported = fastpath::simd::supported();
    println!(
        "=== §Prefill: chunked causal fold, D={feat} dv={dv} d={d}, lengths {lengths:?}, \
         chunks {chunks:?}, simd_supported={simd_supported} ==="
    );
    let mut cells: Vec<Cell> = Vec::new();
    fastpath::simd::set_active(false);
    run_arm("scalar", &lengths, &chunks, d, feat, dv, repeats, &mut cells);
    if fastpath::simd::set_active(true) {
        run_arm("simd", &lengths, &chunks, d, feat, dv, repeats, &mut cells);
    }
    fastpath::simd::reset();
    fastpath::attention::reset_causal_chunk();

    let max_n = lengths.iter().copied().max().unwrap_or(0);
    let best = |arm: &str| -> f64 {
        cells
            .iter()
            .filter(|c| c.arm == arm && c.n == max_n)
            .map(|c| c.speedup)
            .fold(0.0, f64::max)
    };
    let (best_scalar, best_simd) = (best("scalar"), best("simd"));
    let verified = cells
        .iter()
        .all(|c| c.state_bit_identical && c.diff_vs_fold < 1e-5 && c.diff_vs_oracle < 1e-5);
    println!(
        "best chunked speedup at n={max_n}: scalar x{best_scalar:.2}, simd x{best_simd:.2} \
         (verified: {verified})"
    );
    let report = Value::obj(vec![
        ("D", Value::num(feat as f64)),
        ("dv", Value::num(dv as f64)),
        ("d", Value::num(d as f64)),
        ("repeats", Value::num(repeats as f64)),
        ("threads", Value::num(fastpath::parallel::num_threads() as f64)),
        ("simd_supported", Value::Bool(simd_supported)),
        ("max_n", Value::num(max_n as f64)),
        ("speedup_max_n_scalar", Value::num(best_scalar)),
        ("speedup_max_n_simd", Value::num(best_simd)),
        ("verified", Value::Bool(verified)),
        ("cells", Value::Arr(cells.iter().map(Cell::to_json).collect())),
    ]);
    std::fs::write("BENCH_prefill.json", report.to_string())?;
    println!("chunked-vs-sequential grid written to BENCH_prefill.json");
    Ok(())
}
