//! Bench §Serve/route — the consistent-hashing router vs a direct
//! backend connection.
//!
//! Three arms, one process:
//!
//! 1. **direct** — the socket load run straight at a gateway (the
//!    floor the router must chase).
//! 2. **routed** — the identical workload through a [`Router`]
//!    fronting a fresh gateway. Both arms verify bit-exact against
//!    in-process decode; the throughput ratio is gated: the proxy hop
//!    must stay within 10% (`within_10pct` in the JSON — the CI
//!    router-smoke job greps it, `MACFORMER_ROUTE_OVERHEAD` widens the
//!    ratio ceiling for noisy runners).
//! 3. **recovery** — two durable gateways behind a router; streams are
//!    opened and prefilled through the router, the backend holding
//!    streams is stopped, and the measurement is the wall-clock from
//!    "backend gone" to "every orphaned stream remapped to the
//!    survivor and answering its resume probe" (`recovery_ms`).
//!    The full SIGKILL drill with bit-exact replay lives in
//!    `macformer route --kill-node`; this arm times the router's
//!    detect-and-migrate path in-process, where a bench can run it.
//!
//! Knobs (env): MACFORMER_ROUTE_STREAMS (8), MACFORMER_ROUTE_TOKENS
//! (48), MACFORMER_SERVE_D (32), MACFORMER_SERVE_DV (32),
//! MACFORMER_SERVE_FEATURES (64), MACFORMER_SERVE_MIN_BATCH (2),
//! MACFORMER_BENCH_KERNEL (exp), MACFORMER_BENCH_BACKEND (host),
//! MACFORMER_ROUTE_OVERHEAD (1.10), MACFORMER_THREADS.
//!
//! Run with: `cargo bench --bench serve_route`
//!
//! [`Router`]: macformer::serve::Router

use std::io::{Read, Write};
use std::net::{Shutdown, TcpStream};
use std::str::FromStr;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use macformer::attn::{Backend, Kernel};
use macformer::fastpath;
use macformer::serve::loadgen::LoadConfig;
use macformer::serve::net::{run_socket, NetConfig};
use macformer::serve::obs;
use macformer::serve::{
    BackendSpec, DurabilityConfig, EngineSpec, Router, RouterConfig, ServeConfig, Server,
};
use macformer::util::json::Value;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn env_parse<T: FromStr>(name: &str, default: T) -> Result<T>
where
    T::Err: std::fmt::Display,
{
    match std::env::var(name) {
        Err(_) => Ok(default),
        Ok(raw) => T::from_str(&raw).map_err(|e| anyhow!("{name}={raw:?}: {e}")),
    }
}

fn server_for(cfg: &LoadConfig, workers: usize, data_dir: Option<&std::path::Path>) -> Result<Server> {
    let spec = EngineSpec {
        kernel: cfg.kernel,
        backend: cfg.backend,
        head_dim: cfg.head_dim,
        dv: cfg.dv,
        num_features: cfg.num_features,
        seed: cfg.seed,
    };
    let serve = ServeConfig { min_batch: cfg.min_batch, ..ServeConfig::new(cfg.streams, cfg.dv) };
    let net = NetConfig { workers, ..NetConfig::default() };
    let durability = data_dir.map(|dir| {
        let mut d = DurabilityConfig::new(dir.to_string_lossy().into_owned());
        // every tick on disk: the recovery arm kills the node moments
        // after the last prefill and the store must already hold it
        d.sync_every_ticks = 1;
        d
    });
    Server::start(net, spec, serve, cfg.resilience.clone(), durability)
        .map_err(|e| anyhow!("backend start: {e}"))
}

/// One request on a fresh connection (write side half-closed after the
/// send, so the keep-alive server answers and hangs up): (status, body).
fn one_shot(addr: &str, method: &str, path: &str, body: &str) -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(10)))?;
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: bench\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes())?;
    let _ = stream.shutdown(Shutdown::Write);
    let mut buf = Vec::new();
    let _ = stream.read_to_end(&mut buf);
    let text = String::from_utf8_lossy(&buf).into_owned();
    let split = text.find("\r\n\r\n").ok_or_else(|| anyhow!("no response head in {text:?}"))?;
    let status: u16 = text[..split]
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow!("bad status line in {text:?}"))?;
    Ok((status, text[split + 4..].to_string()))
}

/// Arm 3: two durable gateways behind a router; stop the one holding
/// streams; return (recovery_ms, victim_streams, migrations_delta).
fn measure_recovery(cfg: &LoadConfig, base: &std::path::Path) -> Result<(f64, usize, u64)> {
    let dirs = [base.join("node0"), base.join("node1")];
    let mut servers: Vec<Option<Server>> = Vec::new();
    let mut backends = Vec::new();
    for dir in &dirs {
        std::fs::create_dir_all(dir)?;
        let server = server_for(cfg, 8, Some(dir))?;
        backends.push(BackendSpec {
            addr: server.local_addr().to_string(),
            data_dir: Some(dir.clone()),
        });
        servers.push(Some(server));
    }
    let router = Router::start(RouterConfig {
        workers: 4,
        seed: cfg.seed,
        probe_interval: Duration::from_millis(10),
        fail_threshold: 3,
        recover_threshold: 2,
        backends,
        ..RouterConfig::default()
    })
    .map_err(|e| anyhow!("router start: {e}"))?;
    let addr = router.local_addr().to_string();

    // open a small fleet of streams and prefill two rows into each, so
    // the migrated record carries real fold state
    let q: Vec<String> = (0..cfg.head_dim).map(|i| format!("{}", (i % 3) as f32 * 0.25)).collect();
    let v: Vec<String> = (0..cfg.dv).map(|i| format!("{}", (i % 5) as f32 * 0.125)).collect();
    let row = format!("{{\"q\":[{0}],\"k\":[{0}],\"v\":[{1}]}}", q.join(","), v.join(","));
    let mut ids = Vec::new();
    for _ in 0..6 {
        let (status, body) = one_shot(&addr, "POST", "/v1/streams", "{}")?;
        if status != 201 {
            bail!("open through router answered {status}: {body}");
        }
        let rid = body.split('"').nth(3).ok_or_else(|| anyhow!("no id in {body}"))?.to_string();
        for _ in 0..2 {
            let (status, body) = one_shot(&addr, "POST", &format!("/v1/streams/{rid}/prefill"), &row)?;
            if status != 200 {
                bail!("prefill through router answered {status}: {body}");
            }
        }
        ids.push(rid);
    }

    // the victim is whichever backend holds more streams
    let map = router.stream_map();
    let on0 = map.iter().filter(|(_, b)| *b == 0).count();
    let victim = if on0 * 2 >= map.len() { 0 } else { 1 };
    let survivor = 1 - victim;
    let victims: Vec<u64> =
        map.iter().filter(|(_, b)| *b == victim).map(|(sid, _)| *sid).collect();
    if victims.is_empty() {
        bail!("hash ring left backend {victim} empty; nothing to migrate");
    }
    let migrations_before = obs::router_migrations();

    // stop the victim; the clock runs from "gone" to "every orphan
    // remapped to the survivor and answering its resume probe"
    servers[victim].take().expect("victim server").shutdown();
    let started = Instant::now();
    let deadline = started + Duration::from_secs(30);
    loop {
        let map = router.stream_map();
        if victims.iter().all(|sid| map.iter().any(|(s, b)| s == sid && *b == survivor)) {
            break;
        }
        if Instant::now() > deadline {
            bail!("streams still mapped to the dead backend after 30s");
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    for sid in &victims {
        let (status, body) = one_shot(&addr, "GET", &format!("/v1/streams/r-{sid}"), "")?;
        if status != 200 || !body.contains("\"tokens\":2") {
            bail!("migrated stream r-{sid} probe answered {status}: {body}");
        }
    }
    let recovery_ms = started.elapsed().as_secs_f64() * 1e3;
    let migrations = obs::router_migrations() - migrations_before;

    for rid in &ids {
        let _ = one_shot(&addr, "DELETE", &format!("/v1/streams/{rid}"), "");
    }
    router.shutdown();
    if let Some(s) = servers[survivor].take() {
        s.shutdown();
    }
    Ok((recovery_ms, victims.len(), migrations))
}

fn main() -> Result<()> {
    macformer::util::logging::init();
    obs::reset();
    let streams = env_usize("MACFORMER_ROUTE_STREAMS", 8);
    let tokens = env_usize("MACFORMER_ROUTE_TOKENS", 48);
    let kernel: Kernel = env_parse("MACFORMER_BENCH_KERNEL", Kernel::Exp)?;
    let backend: Backend = env_parse("MACFORMER_BENCH_BACKEND", Backend::HostFast)?;
    let overhead_ceiling = env_f64("MACFORMER_ROUTE_OVERHEAD", 1.10);
    let cfg = LoadConfig {
        streams,
        tokens,
        prompt: 0,
        head_dim: env_usize("MACFORMER_SERVE_D", 32),
        dv: env_usize("MACFORMER_SERVE_DV", 32),
        num_features: env_usize("MACFORMER_SERVE_FEATURES", 64),
        kernel,
        backend,
        min_batch: env_usize("MACFORMER_SERVE_MIN_BATCH", 2),
        verify: true,
        ..LoadConfig::default()
    };
    println!(
        "=== §Serve/route: {streams} streams x {tokens} tokens, kernel {kernel}, \
         backend {backend}, {} threads ===",
        fastpath::parallel::num_threads(),
    );

    // --- arm 1: direct to a gateway ---
    let server = server_for(&cfg, streams + 8, None)?;
    let direct = run_socket(&cfg, &server.local_addr().to_string())?;
    server.shutdown();
    println!("direct:\n{}\n", direct.render());

    // --- arm 2: the same workload through the router ---
    let server = server_for(&cfg, streams + 8, None)?;
    let router = Router::start(RouterConfig {
        workers: streams + 2,
        seed: cfg.seed,
        backends: vec![BackendSpec { addr: server.local_addr().to_string(), data_dir: None }],
        ..RouterConfig::default()
    })
    .map_err(|e| anyhow!("router start: {e}"))?;
    let routed = run_socket(&cfg, &router.local_addr().to_string())?;
    router.shutdown();
    server.shutdown();
    println!("routed:\n{}\n", routed.render());

    // --- arm 3: failover recovery time ---
    let base = std::env::temp_dir().join(format!("macformer-route-bench-{}", std::process::id()));
    let recovery = measure_recovery(&cfg, &base);
    let _ = std::fs::remove_dir_all(&base);
    let (recovery_ms, recovered_streams, migrations) = recovery?;

    let overhead = if routed.tokens_per_sec > 0.0 {
        direct.tokens_per_sec / routed.tokens_per_sec
    } else {
        f64::INFINITY
    };
    let within_10pct = overhead <= overhead_ceiling;
    println!(
        "routed {:.0} tok/s vs direct {:.0} tok/s ({overhead:.3}x, ceiling {overhead_ceiling:.2}x); \
         added latency p50 {:+.6}s p99 {:+.6}s; \
         failover recovered {recovered_streams} streams in {recovery_ms:.0} ms ({migrations} migrations)",
        routed.tokens_per_sec,
        direct.tokens_per_sec,
        routed.latency_p50 - direct.latency_p50,
        routed.latency_p99 - direct.latency_p99,
    );

    let doc = Value::obj(vec![
        ("streams", Value::num(streams as f64)),
        ("tokens_per_stream", Value::num(tokens as f64)),
        ("kernel", Value::str(kernel.name())),
        ("threads", Value::num(fastpath::parallel::num_threads() as f64)),
        ("simd_supported", Value::Bool(fastpath::simd::supported())),
        ("direct_tokens_per_sec", Value::num(direct.tokens_per_sec)),
        ("routed_tokens_per_sec", Value::num(routed.tokens_per_sec)),
        ("proxy_overhead", Value::num(overhead)),
        ("overhead_ceiling", Value::num(overhead_ceiling)),
        ("added_latency_p50_s", Value::num(routed.latency_p50 - direct.latency_p50)),
        ("added_latency_p99_s", Value::num(routed.latency_p99 - direct.latency_p99)),
        // CI router-smoke greps the three below
        ("within_10pct", Value::Bool(within_10pct)),
        ("verified", Value::Bool(direct.verified == Some(true) && routed.verified == Some(true))),
        ("http_5xx", Value::num((direct.http_5xx + routed.http_5xx) as f64)),
        ("recovery_ms", Value::num(recovery_ms)),
        ("recovered_streams", Value::num(recovered_streams as f64)),
        ("router_migrations", Value::num(migrations as f64)),
        ("direct", direct.to_json()),
        ("routed", routed.to_json()),
    ]);
    std::fs::write("BENCH_serve_route.json", doc.to_string())?;
    println!("serve/route reports written to BENCH_serve_route.json");

    if direct.verified != Some(true)
        || routed.verified != Some(true)
        || direct.stream_errors + routed.stream_errors > 0
        || direct.http_5xx + routed.http_5xx > 0
    {
        bail!(
            "serve/route degraded: direct verified {:?} ({} errors, {} x 5xx), \
             routed verified {:?} ({} errors, {} x 5xx)",
            direct.verified,
            direct.stream_errors,
            direct.http_5xx,
            routed.verified,
            routed.stream_errors,
            routed.http_5xx
        );
    }
    if !within_10pct {
        bail!(
            "proxy overhead {overhead:.3}x exceeds the {overhead_ceiling:.2}x ceiling \
             (raise MACFORMER_ROUTE_OVERHEAD for a noisy runner)"
        );
    }
    Ok(())
}
