//! Bench E2+E3 — Fig 4a (log NMSE) and Fig 4b (log acceleration ratio) of
//! RMFA_exp vs exact softmax attention, over the paper\'s (length, D) grid.
//!
//! Shapes follow the paper: batch 16 x 8 heads, d = 64, preSBN eps 1e-12.
//! Knobs: MACFORMER_BENCH_LENGTHS / _FEATURES (csv), _REPEATS.
//!
//! Run with: `cargo bench --bench fig4_rmfa_micro`

use macformer::coordinator::microbench;
use macformer::runtime::Registry;

fn env_csv(name: &str, default: &[usize]) -> Vec<usize> {
    std::env::var(name)
        .ok()
        .map(|s| s.split(',').filter_map(|x| x.parse().ok()).collect())
        .unwrap_or_else(|| default.to_vec())
}

fn main() -> anyhow::Result<()> {
    macformer::util::logging::init();
    let reg = Registry::open_default()?;
    let lengths = env_csv("MACFORMER_BENCH_LENGTHS", &reg.micro_lengths);
    let features = env_csv("MACFORMER_BENCH_FEATURES", &reg.micro_features);
    let repeats: usize = std::env::var("MACFORMER_BENCH_REPEATS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3);
    println!(
        "=== E2/E3 / Fig 4: RMFA_exp vs softmax attention (lengths {lengths:?}, D {features:?}, {repeats} repeats) ==="
    );
    let cells = microbench::run_grid(&reg, &lengths, &features, repeats, 7)?;
    println!("{}", microbench::render(&cells));
    std::fs::write(
        "bench_fig4.json",
        microbench::to_json(&cells).to_string(),
    )?;
    println!("raw cells written to bench_fig4.json");
    Ok(())
}
