//! Bench E2+E3 — Fig 4a (log NMSE) and Fig 4b (log acceleration ratio) of
//! RMFA vs exact softmax attention, over the paper's (length, D) grid.
//!
//! Backends (MACFORMER_BENCH_BACKEND, parsed via `Backend::from_str`):
//!   host   (default) — typed `attn` sessions over the `AttentionBackend`
//!          dispatch: the requested tier per cell plus the reference tier
//!          (fast-vs-oracle speedup); no artifacts/PJRT needed. Any
//!          Table-1 kernel via MACFORMER_BENCH_KERNEL (default exp).
//!   reference / auto — same grid, timing that tier instead (rows carry
//!          a "backend" field in bench_fig4.json).
//!   device — the original compiled-HLO path over PJRT (needs
//!          `make artifacts`; exp only).
//!
//! Shapes follow the paper: batch 16 x 8 heads, d = 64, preSBN eps 1e-12
//! (device) / eps 1e-6 denominators (host).
//! Knobs: MACFORMER_BENCH_KERNEL, MACFORMER_BENCH_LENGTHS / _FEATURES
//! (csv), _REPEATS, _GROUPS, MACFORMER_THREADS.
//!
//! Run with: `cargo bench --bench fig4_rmfa_micro`

use std::str::FromStr;

use anyhow::anyhow;

use macformer::attn::{Backend, Kernel};
use macformer::coordinator::microbench;
use macformer::runtime::Registry;

fn env_csv(name: &str, default: &[usize]) -> Vec<usize> {
    std::env::var(name)
        .ok()
        .map(|s| s.split(',').filter_map(|x| x.parse().ok()).collect())
        .unwrap_or_else(|| default.to_vec())
}

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    macformer::util::logging::init();
    let backend_name =
        std::env::var("MACFORMER_BENCH_BACKEND").unwrap_or_else(|_| "host".to_string());
    // typed parses: a typo'd backend or kernel name is a clean error,
    // never a panic
    let backend =
        Backend::from_str(&backend_name).map_err(|e| anyhow!("MACFORMER_BENCH_BACKEND: {e}"))?;
    let kernel_name =
        std::env::var("MACFORMER_BENCH_KERNEL").unwrap_or_else(|_| "exp".to_string());
    let kernel =
        Kernel::from_str(&kernel_name).map_err(|e| anyhow!("MACFORMER_BENCH_KERNEL: {e}"))?;
    let repeats = env_usize("MACFORMER_BENCH_REPEATS", 3);
    if backend == Backend::Device {
        if kernel != Kernel::Exp {
            anyhow::bail!(
                "the device grid runs precompiled rmfa_exp artifacts; \
                 MACFORMER_BENCH_KERNEL={kernel} is host-only (unset MACFORMER_BENCH_BACKEND)"
            );
        }
        let reg = Registry::open_default()?;
        let lengths = env_csv("MACFORMER_BENCH_LENGTHS", &reg.micro_lengths);
        let features = env_csv("MACFORMER_BENCH_FEATURES", &reg.micro_features);
        println!(
            "=== E2/E3 / Fig 4 [device]: RMFA_exp vs softmax attention (lengths {lengths:?}, D {features:?}, {repeats} repeats) ==="
        );
        let cells = microbench::run_grid(&reg, &lengths, &features, repeats, 7)?;
        println!("{}", microbench::render(&cells));
        std::fs::write("bench_fig4.json", microbench::to_json(&cells).to_string())?;
        println!("raw cells written to bench_fig4.json");
        return Ok(());
    }

    let lengths = env_csv("MACFORMER_BENCH_LENGTHS", &[256, 1024, 2048]);
    let features = env_csv("MACFORMER_BENCH_FEATURES", &[64, 128]);
    let groups = env_usize("MACFORMER_BENCH_GROUPS", 16 * 8);
    println!(
        "=== E2/E3 / Fig 4 [host sessions, {backend} tier]: RMFA_{kernel} vs softmax attention \
         (lengths {lengths:?}, D {features:?}, {repeats} repeats, {groups} batch x head problems, {} threads) ===",
        macformer::fastpath::parallel::num_threads()
    );
    let cells =
        microbench::run_host_grid(kernel, backend, &lengths, &features, repeats, 7, groups, 64)?;
    println!("{}", microbench::render_host(&cells));
    std::fs::write("bench_fig4.json", microbench::host_to_json(&cells).to_string())?;
    println!("raw cells written to bench_fig4.json");
    Ok(())
}
