//! Minimal offline shim of `anyhow`: a string-backed error type plus the
//! `anyhow!` / `bail!` / `ensure!` macros and the `Context` extension
//! trait. Covers exactly the surface the `macformer` crate uses.

use std::fmt;

/// A string-backed dynamic error, optionally carrying the typed cause
/// it was built from so [`Error::downcast_ref`] can recover it.
///
/// Deliberately does NOT implement `std::error::Error`, so the blanket
/// `From<E: std::error::Error>` below does not collide with the
/// reflexive `From<Error>` impl from core (same trick as upstream).
pub struct Error {
    msg: String,
    cause: Option<Box<dyn std::any::Any + Send + Sync>>,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), cause: None }
    }

    /// Wrap a typed error, keeping it recoverable via
    /// [`Error::downcast_ref`] (subset of upstream `Error::new`).
    pub fn new<E: std::error::Error + Send + Sync + 'static>(e: E) -> Error {
        Error { msg: e.to_string(), cause: Some(Box::new(e)) }
    }

    /// The typed cause this error was built from via [`Error::new`],
    /// if it was and the type matches. Context wrappers drop the
    /// cause (the shim keeps a message chain, not an error chain).
    pub fn downcast_ref<E: 'static>(&self) -> Option<&E> {
        self.cause.as_ref()?.downcast_ref::<E>()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::new(e)
    }
}

/// `anyhow::Result<T>`: `Result` defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to an error (subset of upstream: context is prepended
/// to the cause's message).
pub trait Context<T, E>: Sized {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T, core::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(ctx))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] if the condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/real/path/42")?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!format!("{e}").is_empty());
        // `?` routes through `Error::new`, so the typed cause survives
        assert!(e.downcast_ref::<std::io::Error>().is_some());
        assert!(e.downcast_ref::<std::fmt::Error>().is_none());
    }

    #[derive(Debug, PartialEq)]
    struct Typed(u32);
    impl fmt::Display for Typed {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(f, "typed {}", self.0)
        }
    }
    impl std::error::Error for Typed {}

    #[test]
    fn new_keeps_the_typed_cause_recoverable() {
        let e = Error::new(Typed(9));
        assert_eq!(format!("{e}"), "typed 9");
        assert_eq!(e.downcast_ref::<Typed>(), Some(&Typed(9)));
        assert!(Error::msg("plain").downcast_ref::<Typed>().is_none());
    }

    #[test]
    fn macros_and_context() {
        let x = 3;
        let e = anyhow!("bad value {x}");
        assert_eq!(format!("{e}"), "bad value 3");
        let e2: Result<()> = Err(anyhow!("inner")).context("outer");
        assert_eq!(format!("{}", e2.unwrap_err()), "outer: inner");
        fn f(ok: bool) -> Result<u32> {
            ensure!(ok, "not ok");
            Ok(7)
        }
        assert_eq!(f(true).unwrap(), 7);
        assert_eq!(format!("{}", f(false).unwrap_err()), "not ok");
    }
}
