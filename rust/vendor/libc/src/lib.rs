//! Minimal offline shim of `libc`: exactly the `getrusage` surface used
//! by `macformer::util::peak_rss_bytes`, plus the `signal(2)` /
//! `kill(2)` surface the serve gateway uses to catch `SIGTERM` for
//! graceful drain and to forward it to spawned backend nodes.
//! Struct layout matches glibc on 64-bit Linux (two `timeval`s
//! followed by fourteen `c_long` fields).

#![allow(non_camel_case_types)]

// This shim hardcodes the glibc/64-bit-Linux ABI. On any other target
// the struct layout (and on Windows, the symbol itself) would be wrong
// — fail the build loudly instead of corrupting memory at run time.
#[cfg(not(target_os = "linux"))]
compile_error!(
    "the vendored libc shim only provides the Linux/glibc rusage layout; \
     swap the real libc crate into rust/Cargo.toml for other targets"
);

pub type c_int = i32;
pub type c_long = i64;

#[repr(C)]
#[derive(Debug, Clone, Copy)]
pub struct timeval {
    pub tv_sec: c_long,
    pub tv_usec: c_long,
}

#[repr(C)]
#[derive(Debug, Clone, Copy)]
pub struct rusage {
    pub ru_utime: timeval,
    pub ru_stime: timeval,
    pub ru_maxrss: c_long,
    pub ru_ixrss: c_long,
    pub ru_idrss: c_long,
    pub ru_isrss: c_long,
    pub ru_minflt: c_long,
    pub ru_majflt: c_long,
    pub ru_nswap: c_long,
    pub ru_inblock: c_long,
    pub ru_oublock: c_long,
    pub ru_msgsnd: c_long,
    pub ru_msgrcv: c_long,
    pub ru_nsignals: c_long,
    pub ru_nvcsw: c_long,
    pub ru_nivcsw: c_long,
}

pub const RUSAGE_SELF: c_int = 0;

/// `SIGTERM` on Linux (the value is uniform across architectures).
pub const SIGTERM: c_int = 15;

/// `SIGKILL` on Linux (uniform across architectures).
pub const SIGKILL: c_int = 9;

/// A process id, as `kill(2)` takes it.
pub type pid_t = i32;

/// A `signal(2)` disposition: the address of an `extern "C"` handler
/// (or 0 / 1 for `SIG_DFL` / `SIG_IGN`).
pub type sighandler_t = usize;

extern "C" {
    pub fn getrusage(who: c_int, usage: *mut rusage) -> c_int;
    pub fn signal(signum: c_int, handler: sighandler_t) -> sighandler_t;
    pub fn kill(pid: pid_t, sig: c_int) -> c_int;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn getrusage_reports_positive_maxrss() {
        // SAFETY: plain libc call with an out-param struct we own.
        unsafe {
            let mut ru: rusage = std::mem::zeroed();
            assert_eq!(getrusage(RUSAGE_SELF, &mut ru), 0);
            assert!(ru.ru_maxrss > 0);
        }
    }
}
