//! Minimal offline shim of the `log` facade: the five level macros, the
//! `Log` trait, and the global logger / max-level registry. Covers
//! exactly the surface the `macformer` crate uses.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity level of a log record.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl Level {
    pub fn as_str(&self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.pad(self.as_str())
    }
}

/// Global verbosity filter.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata of a record (level + target module path).
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }
    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record, borrowed for the duration of the `log` call.
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }
    pub fn level(&self) -> Level {
        self.metadata.level
    }
    pub fn target(&self) -> &'a str {
        self.metadata.target
    }
    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A log sink.
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

struct NopLogger;

impl Log for NopLogger {
    fn enabled(&self, _m: &Metadata) -> bool {
        false
    }
    fn log(&self, _r: &Record) {}
    fn flush(&self) {}
}

static NOP: NopLogger = NopLogger;
static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(0);

/// Error returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

/// Install the global logger (first caller wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// The installed logger (a no-op sink before `set_logger`).
pub fn logger() -> &'static dyn Log {
    match LOGGER.get() {
        Some(l) => *l,
        None => &NOP,
    }
}

pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Implementation detail of the level macros — not public API.
#[doc(hidden)]
pub fn __log(level: Level, target: &str, args: fmt::Arguments<'_>) {
    if level <= max_level() {
        let record = Record { metadata: Metadata { level, target }, args };
        logger().log(&record);
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => {
        $crate::__log($crate::Level::Error, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => {
        $crate::__log($crate::Level::Warn, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => {
        $crate::__log($crate::Level::Info, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => {
        $crate::__log($crate::Level::Debug, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => {
        $crate::__log($crate::Level::Trace, module_path!(), format_args!($($arg)+))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering_vs_filter() {
        assert!(Level::Error <= LevelFilter::Info);
        assert!(Level::Info <= LevelFilter::Info);
        assert!(!(Level::Debug <= LevelFilter::Info));
    }

    #[test]
    fn macros_do_not_panic_without_logger() {
        info!("hello {}", 42);
        warn!("warned");
        error!("bad");
        debug!("dbg");
        trace!("trc");
    }
}
