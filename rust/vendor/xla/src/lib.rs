//! Offline **API stub** of the vendored `xla` PJRT bindings.
//!
//! Exposes the exact type and method surface `macformer::runtime` is
//! written against, but with no native XLA library behind it: every
//! device entry point returns a descriptive `Err`. Callers gate on
//! `PjRtClient::cpu()` failing and fall back to the pure-Rust host
//! compute path (`macformer::fastpath` / `macformer::reference`), so
//! builds, unit tests, property tests, and the host benches all work
//! on machines without the PJRT plugin. Swapping the real bindings back
//! in is a path change in `rust/Cargo.toml` — no call-site edits.

use std::borrow::Borrow;
use std::fmt;
use std::rc::Rc;

/// Error type mirroring the real bindings' error enum (message-only here).
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT/XLA native runtime is not present in this build \
         (offline xla stub); the host fastpath and reference kernels \
         remain available"
    ))
}

/// Element types of array literals (subset used by the runtime).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    F32,
    F64,
    S32,
    S64,
    U32,
    U64,
    Tuple,
}

/// Host types that map onto an [`ElementType`].
pub trait NativeType: Copy + 'static {
    const TY: ElementType;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
}
impl NativeType for f64 {
    const TY: ElementType = ElementType::F64;
}
impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
}
impl NativeType for i64 {
    const TY: ElementType = ElementType::S64;
}
impl NativeType for u32 {
    const TY: ElementType = ElementType::U32;
}
impl NativeType for u64 {
    const TY: ElementType = ElementType::U64;
}

/// Handle to a PJRT client. `Rc`-backed like the real bindings, hence
/// intentionally neither `Send` nor `Sync`.
#[derive(Clone)]
pub struct PjRtClient {
    _handle: Rc<()>,
}

impl PjRtClient {
    /// In the stub there is no native plugin to load, so this always
    /// fails; callers treat the error as "PJRT unavailable".
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn platform_version(&self) -> String {
        "offline-stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

/// Parsed HLO module text.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(unavailable(&format!("HloModuleProto::from_text_file({path:?})")))
    }
}

/// An XLA computation wrapping a parsed module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute over device buffers; generic over owned or borrowed
    /// buffer slices like the real bindings.
    pub fn execute_b<B: Borrow<PjRtBuffer>>(&self, _args: &[B]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// A device-resident buffer.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Dimensions of a (non-tuple) array literal.
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// A host-side literal (array or tuple).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn ty(&self) -> Result<ElementType> {
        Err(unavailable("Literal::ty"))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(unavailable("Literal::array_shape"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::decompose_tuple"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("PJRT"), "{msg}");
    }
}
