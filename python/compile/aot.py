"""AOT pipeline: lower every model/kernel module to HLO text + manifest.

This is the ONLY place python touches the artifact directory; after
`make artifacts` the Rust binary is self-contained. Interchange format is
HLO *text* (not serialized HloModuleProto): jax >= 0.5 emits protos with
64-bit instruction ids which xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Module families (DESIGN.md §Artifact contract):

  <task>.<variant>.init      (seed u32[])                -> (state..., key)
  <task>.<variant>.train     (state..., batch..., key)   -> (state..., loss, key)
  <task>.<variant>.eval      (state..., batch..., key)   -> (loss, metric)
  translation.<variant>.generate (params..., prompt, key) -> tokens
  micro.softmax.n<len>       (q, k, v)                   -> out
  micro.rmfa_exp.n<len>.D<D> (q, k, v, key)              -> out

"state" is params + Adam state, flattened in jax pytree order; the Rust
coordinator treats it as an opaque ordered buffer list (device-resident,
threaded through train steps via execute_b).

Usage: python -m compile.aot --out ../artifacts [--only REGEX] [--force]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import re
import sys
import time
from typing import Any, Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import experiments as E
from compile import model as M
from compile import ppsbn
from compile import train as T
from compile.kernels import rmfa as krmfa
from compile.kernels import softmax_attn as ksoftmax


def to_hlo_text(lowered) -> str:
    """jax lowering -> XLA HLO text via stablehlo (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype) -> Dict[str, Any]:
    return {"shape": list(int(s) for s in shape), "dtype": str(np.dtype(dtype))}


def _specs(shaped) -> List[Dict[str, Any]]:
    return [_spec(s.shape, s.dtype) for s in shaped]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


# ---------------------------------------------------------------------------
# model-module builders
# ---------------------------------------------------------------------------


class ModelFamily:
    """init/train/eval(/generate) lowering for one (task, variant) cell."""

    def __init__(self, task: E.TaskSpec, variant: str, ppsbn_flag=None,
                 suffix: str = ""):
        self.task = task
        self.variant = variant
        self.cfg = E.model_config(task, variant, ppsbn=ppsbn_flag)
        self.opt = E.opt_config(task)
        self.plan = M.make_rmf_plan(self.cfg) if self.cfg.kernel_name else None
        self.name = f"{task.name}.{variant}{suffix}"
        # shape-only init to get the flattening contract
        pshape = jax.eval_shape(
            lambda k: M.init_params(k, self.cfg), _sds((2,), jnp.uint32)
        )
        self.p_flat, self.p_tree = jax.tree_util.tree_flatten(pshape)
        oshape = jax.eval_shape(
            lambda: T.init_opt_state(
                jax.tree_util.tree_map(
                    lambda l: jnp.zeros(l.shape, l.dtype), pshape
                )
            )
        )
        self.o_flat, self.o_tree = jax.tree_util.tree_flatten(oshape)

    # -- state (de)flattening ------------------------------------------------
    def _unflatten(self, args):
        np_, no = len(self.p_flat), len(self.o_flat)
        p = jax.tree_util.tree_unflatten(self.p_tree, args[:np_])
        s = jax.tree_util.tree_unflatten(self.o_tree, args[np_:np_ + no])
        return p, s, args[np_ + no:]

    def _flatten(self, params, opt_state):
        return tuple(jax.tree_util.tree_leaves(params)) + tuple(
            jax.tree_util.tree_leaves(opt_state)
        )

    # -- batch plumbing -------------------------------------------------------
    def batch_specs(self) -> List[Dict[str, Any]]:
        t, b, n = self.task, self.task.batch, self.task.seq_len
        if t.task == "cls":
            return [
                {"name": "tokens", **_spec((b, n), np.int32)},
                {"name": "mask", **_spec((b, n), np.int32)},
                {"name": "labels", **_spec((b,), np.int32)},
            ]
        if t.task == "retrieval":
            return [
                {"name": "tokens1", **_spec((b, n), np.int32)},
                {"name": "mask1", **_spec((b, n), np.int32)},
                {"name": "tokens2", **_spec((b, n), np.int32)},
                {"name": "mask2", **_spec((b, n), np.int32)},
                {"name": "labels", **_spec((b,), np.int32)},
            ]
        return [
            {"name": "tokens", **_spec((b, n), np.int32)},
            {"name": "loss_mask", **_spec((b, n), np.float32)},
        ]

    def _batch_sds(self):
        out = []
        for s in self.batch_specs():
            out.append(_sds(tuple(s["shape"]), np.dtype(s["dtype"])))
        return out

    def _batch_dict(self, arrays):
        names = [s["name"] for s in self.batch_specs()]
        d = dict(zip(names, arrays))
        if "loss_mask" in d:
            d["loss_mask"] = d["loss_mask"].astype(jnp.float32)
        return d

    # -- lowered entry points --------------------------------------------------
    def lower_init(self):
        def fn(seed):
            key = jax.random.PRNGKey(seed)
            pkey, tkey = jax.random.split(key)
            params = M.init_params(pkey, self.cfg)
            opt_state = T.init_opt_state(params)
            return self._flatten(params, opt_state) + (tkey,)

        return jax.jit(fn, keep_unused=True).lower(_sds((), jnp.uint32))

    def lower_train(self):
        def fn(*args):
            params, opt_state, rest = self._unflatten(args)
            batch, key = self._batch_dict(rest[:-1]), rest[-1]
            p2, s2, loss, k2 = T.train_step(
                params, opt_state, batch, key, self.cfg, self.plan, self.opt
            )
            return self._flatten(p2, s2) + (loss, k2)

        args = (
            [_sds(l.shape, l.dtype) for l in self.p_flat]
            + [_sds(l.shape, l.dtype) for l in self.o_flat]
            + self._batch_sds()
            + [_sds((2,), jnp.uint32)]
        )
        return jax.jit(fn, keep_unused=True).lower(*args)

    def lower_eval(self):
        # eval takes params only (no Adam state)
        def fn2(*args):
            np_ = len(self.p_flat)
            params = jax.tree_util.tree_unflatten(self.p_tree, args[:np_])
            rest = args[np_:]
            batch, key = self._batch_dict(rest[:-1]), rest[-1]
            return T.eval_step(params, batch, key, self.cfg, self.plan)

        args = (
            [_sds(l.shape, l.dtype) for l in self.p_flat]
            + self._batch_sds()
            + [_sds((2,), jnp.uint32)]
        )
        return jax.jit(fn2, keep_unused=True).lower(*args)

    def o_flat_zeros(self):
        return [jnp.zeros(l.shape, l.dtype) for l in self.o_flat]

    def lower_generate(self):
        assert self.task.task == "lm"

        def fn(*args):
            np_ = len(self.p_flat)
            params = jax.tree_util.tree_unflatten(self.p_tree, args[:np_])
            prompt, key = args[np_], args[np_ + 1]
            return T.generate(
                params, prompt, E.TRANS_PROMPT_LEN, key, self.cfg, self.plan,
                E.TRANS_TGT_MAX,
            )

        args = (
            [_sds(l.shape, l.dtype) for l in self.p_flat]
            + [_sds((self.task.batch, self.task.seq_len), jnp.int32),
               _sds((2,), jnp.uint32)]
        )
        return jax.jit(fn, keep_unused=True).lower(*args)

    # -- manifest rows ----------------------------------------------------------
    def modules(self) -> List[Dict[str, Any]]:
        t = self.task
        base = {
            "task": t.name,
            "variant": self.variant,
            "family": self.name,
            "batch": t.batch,
            "seq_len": t.seq_len,
            "vocab_size": t.vocab_size,
            "num_classes": t.num_classes,
            "n_params": len(self.p_flat),
            "n_opt": len(self.o_flat),
            "param_specs": _specs(self.p_flat),
            "opt_specs": _specs(self.o_flat),
            "config": {
                "attn": self.cfg.attn,
                "ppsbn": self.cfg.ppsbn,
                "d_model": self.cfg.d_model,
                "n_layers": self.cfg.n_layers,
                "n_heads": self.cfg.n_heads,
                "feature_dim": self.cfg.feature_dim,
                "p": self.cfg.p,
                "causal": self.cfg.causal,
                "task": self.cfg.task,
            },
            "batch_specs": self.batch_specs(),
        }
        rows = [
            {**base, "name": f"{self.name}.init", "role": "init",
             "lower": self.lower_init},
            {**base, "name": f"{self.name}.train", "role": "train",
             "lower": self.lower_train},
            {**base, "name": f"{self.name}.eval", "role": "eval",
             "lower": self.lower_eval},
        ]
        if t.task == "lm":
            rows.append(
                {**base, "name": f"{self.name}.generate", "role": "generate",
                 "lower": self.lower_generate,
                 "prompt_len": E.TRANS_PROMPT_LEN,
                 "max_new": E.TRANS_TGT_MAX}
            )
        return rows


# ---------------------------------------------------------------------------
# Fig-4 micro modules
# ---------------------------------------------------------------------------


def micro_modules() -> List[Dict[str, Any]]:
    """Attention micro-benchmarks: softmax vs RMFA_exp on (G, n, d) inputs.

    Both apply the same preSBN preprocessing in-graph (paper: generated
    data is preprocessed with preSBN, eps=1e-12) so their outputs are
    directly comparable for the Fig-4a NMSE and 4b wall-time ratio.
    """
    g = E.MICRO_B * E.MICRO_H
    d = E.MICRO_D
    rows: List[Dict[str, Any]] = []

    def presbn4(q, k):
        q4 = q.reshape(E.MICRO_B, E.MICRO_H, -1, d)
        k4 = k.reshape(E.MICRO_B, E.MICRO_H, -1, d)
        q4 = ppsbn.pre_sbn(q4, eps=E.MICRO_EPS)
        k4 = ppsbn.pre_sbn(k4, eps=E.MICRO_EPS)
        return q4.reshape(g, -1, d), k4.reshape(g, -1, d)

    for n in E.MICRO_LENGTHS:
        def sm_fn(q, k, v, _n=n):
            q, k = presbn4(q, k)
            return ksoftmax.softmax_attn(q, k, v)

        def sm_lower(_fn=sm_fn, _n=n):
            args = [_sds((g, _n, d), jnp.float32)] * 3
            return jax.jit(_fn, keep_unused=True).lower(*args)

        rows.append({
            "name": f"micro.softmax.n{n}", "role": "micro_softmax",
            "task": "micro", "variant": "softmax", "seq_len": n,
            "batch": E.MICRO_B, "heads": E.MICRO_H, "d_head": d,
            "lower": sm_lower,
        })

        for D in E.MICRO_FEATURES:
            cfg = M.ModelConfig(
                attn="mac_exp", feature_dim=D, seq_len=n, p=2.0,
                d_model=d, n_heads=1, use_pallas=True,
            )
            # d_head == d for the micro models (one synthetic head).
            plan_cfg = M.ModelConfig(attn="mac_exp", feature_dim=D, p=2.0)
            plan = M.make_rmf_plan(plan_cfg)

            def rmfa_fn(q, k, v, key, _plan=plan, _n=n, _D=D):
                q, k = presbn4(q, k)
                omegas = M._draw_bucket_omegas(key, _plan, d)
                bscales = [jnp.asarray(s, jnp.float32)
                           for s in _plan.bucket_scales]
                from compile.kernels import rmf as krmf
                root = d ** 0.25
                phi_q = krmf.rmf_features_pallas(q / root, omegas, bscales)
                phi_k = krmf.rmf_features_pallas(k / root, omegas, bscales)
                return krmfa.linear_attn_bidir(phi_q, phi_k, v)

            def rmfa_lower(_fn=rmfa_fn, _n=n):
                args = [_sds((g, _n, d), jnp.float32)] * 3 + [
                    _sds((2,), jnp.uint32)
                ]
                return jax.jit(_fn, keep_unused=True).lower(*args)

            rows.append({
                "name": f"micro.rmfa_exp.n{n}.D{D}", "role": "micro_rmfa",
                "task": "micro", "variant": "mac_exp", "seq_len": n,
                "feature_dim": D, "batch": E.MICRO_B, "heads": E.MICRO_H,
                "d_head": d, "lower": rmfa_lower,
            })
    return rows


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def all_modules() -> List[Dict[str, Any]]:
    rows: List[Dict[str, Any]] = []
    for tname, variant in E.grid():
        rows.extend(ModelFamily(E.TASKS[tname], variant).modules())
    for tname, variant, pp in E.fig3_cells():
        suffix = ".ppsbn" if pp else ".base"
        rows.extend(
            ModelFamily(E.TASKS[tname], variant, ppsbn_flag=pp,
                        suffix=suffix).modules()
        )
    rows.extend(micro_modules())
    return rows


def _input_hash() -> str:
    """Hash of the compile-path sources; drives incremental rebuilds."""
    h = hashlib.sha256()
    root = os.path.dirname(os.path.abspath(__file__))
    for dirpath, _, files in sorted(os.walk(root)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(dirpath, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()[:16]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default="", help="regex filter on module name")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    rows = all_modules()
    if args.list:
        for r in rows:
            print(r["name"])
        return

    os.makedirs(args.out, exist_ok=True)
    stamp_path = os.path.join(args.out, "manifest.json")
    ihash = _input_hash()
    old = {}
    if os.path.exists(stamp_path) and not args.force:
        with open(stamp_path) as f:
            old = json.load(f)
        if old.get("input_hash") == ihash and not args.only:
            print(f"artifacts up to date (hash {ihash}); skipping")
            return

    pat = re.compile(args.only) if args.only else None
    manifest_rows = []
    t_total = time.time()
    for r in rows:
        name = r["name"]
        fname = name + ".hlo.txt"
        path = os.path.join(args.out, fname)
        row = {k: v for k, v in r.items() if k != "lower"}
        row["file"] = fname
        if pat and not pat.search(name):
            # keep prior entry if the file exists
            manifest_rows.append(row)
            continue
        t0 = time.time()
        lowered = r["lower"]()
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        print(f"[aot] {name}: {len(text)/1e3:.0f} kB in {time.time()-t0:.1f}s",
              flush=True)
        manifest_rows.append(row)

    manifest = {
        "input_hash": ihash,
        "generated_unix": int(time.time()),
        "jax_version": jax.__version__,
        "modules": manifest_rows,
        "micro": {
            "lengths": list(E.MICRO_LENGTHS),
            "features": list(E.MICRO_FEATURES),
            "batch": E.MICRO_B, "heads": E.MICRO_H, "d_head": E.MICRO_D,
        },
        "translation": {
            "src_max": E.TRANS_SRC_MAX, "tgt_max": E.TRANS_TGT_MAX,
            "seq": E.TRANS_SEQ, "prompt_len": E.TRANS_PROMPT_LEN,
        },
    }
    with open(stamp_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {len(manifest_rows)} modules in "
          f"{time.time()-t_total:.0f}s -> {stamp_path}")


if __name__ == "__main__":
    main()
