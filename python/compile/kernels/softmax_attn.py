"""Pallas kernel: exact softmax attention baseline (Layer 1).

The O(n^2 d) comparator for the Fig-4 micro-benchmarks and the `softmax`
model variant of Table 2. Implements the online-softmax streaming schedule
(row blocks of Q resident in VMEM; K/V swept in chunks with running
max/denominator), i.e. the standard flash-attention decomposition — the
TPU analogue of the paper baseline's fused CUDA softmax.

Padding is handled by an additive per-key bias (0 for real tokens, -1e9
for pads) so the kernel needs no boolean mask plumbing.

VMEM for defaults (bm=128, chunk=128, d=64): q 32 KB, k/v chunks 64 KB,
acc 32 KB, stats 1 KB ~= 130 KB.

interpret=True on this image (see rmf.py docstring).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_HIGH = jax.lax.Precision.HIGHEST


def _softmax_attn_kernel(q_ref, k_ref, v_ref, kb_ref, o_ref, *, nb: int,
                         bk: int, causal: bool, scale: float):
    """Grid (G, n/bm): one Q row-block per program; online softmax over K.

    Running statistics (row max m, denominator l) are carried functionally
    through the chunk loop; the accumulator is rescaled when m improves.
    """
    bm = q_ref.shape[1]
    d = v_ref.shape[-1]
    qi = pl.program_id(1)
    q = q_ref[0] * scale  # (bm, d)

    def body(c, carry):
        acc, m, l = carry
        sl = (0, pl.dslice(c * bk, bk), slice(None))
        k = pl.load(k_ref, sl)  # (bk, d)
        v = pl.load(v_ref, sl)  # (bk, d)
        kb = pl.load(kb_ref, (0, pl.dslice(c * bk, bk)))  # (bk,)
        s = jnp.dot(q, k.T, precision=_HIGH) + kb[None, :]  # (bm, bk)
        if causal:
            rows = qi * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, bk), 0)
            cols = c * bk + jax.lax.broadcasted_iota(jnp.int32, (bm, bk), 1)
            s = jnp.where(rows >= cols, s, -1e9)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)  # (bm, bk)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * alpha + jnp.dot(p, v, precision=_HIGH)
        return acc_new, m_new, l_new

    init = (
        jnp.zeros((bm, d), dtype=jnp.float32),
        jnp.full((bm, 1), -1e30, dtype=jnp.float32),
        jnp.zeros((bm, 1), dtype=jnp.float32),
    )
    acc, m, l = jax.lax.fori_loop(0, nb, body, init)
    o_ref[0] = acc / l


def _softmax_attn_impl(q, k, v, key_bias=None, *, causal: bool = False,
                       block_m: int = 128, block_k: int = 128,
                       interpret: bool = True):
    """Exact softmax attention over (G, n, d) inputs (G = batch*heads).

    Args:
      q, k, v:  (G, n, d).
      key_bias: (G, n) additive logit bias per key (None -> zeros); use
                -1e9 at padded positions.
      causal:   autoregressive masking.
    Returns: (G, n, d) f32.
    """
    g, n, d = q.shape
    bm = min(block_m, n)
    bk = min(block_k, n)
    assert n % bm == 0 and n % bk == 0, f"n={n} bm={bm} bk={bk}"
    if key_bias is None:
        key_bias = jnp.zeros((g, n), dtype=jnp.float32)
    scale = 1.0 / (d**0.5)
    return pl.pallas_call(
        functools.partial(
            _softmax_attn_kernel, nb=n // bk, bk=bk, causal=causal,
            scale=scale,
        ),
        grid=(g, n // bm),
        in_specs=[
            pl.BlockSpec((1, bm, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, n, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, n, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, n), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((g, n, d), jnp.float32),
        interpret=interpret,
    )(q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
      key_bias.astype(jnp.float32))


# ---------------------------------------------------------------------------
# autodiff: Pallas forward, jnp backward
# ---------------------------------------------------------------------------
#
# The backward recomputes the exact softmax weights in jnp (O(n^2) time and
# memory) — faithful to the base-Transformer cost model of Table 2, whose
# whole point is that the exact baseline *is* quadratic. g flows as:
#   w = softmax(s),  out = w v
#   d_v = w^T g;  d_w = g v^T;  d_s = w * (d_w - sum(d_w * w))
#   d_q = d_s k / sqrt(d);  d_k = d_s^T q / sqrt(d)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def softmax_attn(q, k, v, key_bias=None, causal=False, block_m=128,
                 block_k=128, interpret=True):
    """Exact softmax attention (differentiable); see _softmax_attn_impl."""
    return _softmax_attn_impl(
        q, k, v, key_bias, causal=causal, block_m=block_m, block_k=block_k,
        interpret=interpret,
    )


def _sm_fwd(q, k, v, key_bias, causal, block_m, block_k, interpret):
    out = _softmax_attn_impl(
        q, k, v, key_bias, causal=causal, block_m=block_m, block_k=block_k,
        interpret=interpret,
    )
    return out, (q, k, v, key_bias)


def _sm_bwd(causal, block_m, block_k, interpret, res, g):
    q, k, v, key_bias = res
    d = q.shape[-1]
    scale = 1.0 / (d**0.5)
    s = jnp.einsum("gnd,gmd->gnm", q, k) * scale
    if key_bias is not None:
        s = s + key_bias[:, None, :]
    if causal:
        n = s.shape[-2]
        tril = jnp.tril(jnp.ones((n, n), dtype=bool))
        s = jnp.where(tril, s, -1e9)
    w = jax.nn.softmax(s, axis=-1)
    d_v = jnp.einsum("gnm,gnd->gmd", w, g)
    d_w = jnp.einsum("gnd,gmd->gnm", g, v)
    d_s = w * (d_w - jnp.sum(d_w * w, axis=-1, keepdims=True))
    d_q = jnp.einsum("gnm,gmd->gnd", d_s, k) * scale
    d_k = jnp.einsum("gnm,gnd->gmd", d_s, q) * scale
    d_bias = None if key_bias is None else jnp.sum(d_s, axis=-2)
    return d_q, d_k, d_v, d_bias


softmax_attn.defvjp(_sm_fwd, _sm_bwd)
