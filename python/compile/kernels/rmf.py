"""Pallas kernel: Random Maclaurin Feature projection (Layer 1).

Computes one *degree bucket* of the RMF map: given rows x in R^(M x d) and a
bank of eta Rademacher direction matrices W in {+-1}^(eta x d x Db), emit

    out[m, i] = scale[i] * prod_{j=1..eta} (x[m, :] @ W[j, :, i])

The full Phi(x) is the bucket-major concatenation over eta (see
compile.rmfa_module / ref.rmf_features_bucketed), times 1/sqrt(D).

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles rows HBM->VMEM
in blocks of `block_m`; the eta chained GEMMs are MXU work on a resident
(block_m, Db) f32 accumulator; W (eta*d*Db) stays VMEM-resident across the
row sweep. VMEM footprint for the default config (block_m=1024, d=32, Db<=128,
eta<=8): 1024*128*4 (acc) + 8*32*128*4 (W) + 1024*32*4 (x) ~= 772 KB —
comfortably inside a TPU core's ~16 MB. block_m was raised 128 -> 1024 in
the §Perf pass: on the interpret-mode CPU path the grid loop overhead
dominates (8.3 s/step -> 3.4 s/step on the lra_text cell), and on TPU the
larger row tile amortizes the W bank residency across 8x more MXU work.

On this image Pallas runs with interpret=True, which lowers the kernel body
to plain HLO so the Rust CPU PJRT client can execute it (real-TPU Mosaic
custom-calls cannot run on CPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmf_bucket_kernel(x_ref, w_ref, scale_ref, o_ref, *, eta: int):
    """One grid step: rows block (block_m, d) -> features block (block_m, Db)."""
    x = x_ref[...]  # (block_m, d)
    acc = jnp.ones((x.shape[0], o_ref.shape[1]), dtype=jnp.float32)
    # Chained product of projections: static unroll over the bucket degree.
    for j in range(eta):
        acc = acc * jnp.dot(x, w_ref[j], precision=jax.lax.Precision.HIGHEST)
    o_ref[...] = acc * scale_ref[...][None, :]


def rmf_bucket(x, w, scale, *, block_m: int = 128, interpret: bool = True):
    """Apply one RMF degree bucket to a row matrix.

    Args:
      x:     (M, d) input rows (already divided by d^(1/4) by the caller).
      w:     (eta, d, Db) Rademacher directions for this bucket; eta == 0
             (the constant features) is handled without a kernel launch.
      scale: (Db,) per-feature prefactor sqrt(a_N * p^(N+1)).
      block_m: row tile size (the HBM->VMEM streaming granularity).

    Returns: (M, Db) feature block, f32. Caller concatenates buckets and
    multiplies by 1/sqrt(D).
    """
    m, d = x.shape
    eta, dw, db = w.shape
    assert dw == d, f"direction dim {dw} != input dim {d}"
    if eta == 0:
        return jnp.broadcast_to(scale[None, :], (m, db)).astype(jnp.float32)
    if m % block_m != 0:
        # Pad rows to the tile size; callers slice the result back.
        pad = block_m - m % block_m
        out = rmf_bucket(
            jnp.pad(x, ((0, pad), (0, 0))), w, scale,
            block_m=block_m, interpret=interpret,
        )
        return out[:m]
    grid = (m // block_m,)
    return pl.pallas_call(
        functools.partial(_rmf_bucket_kernel, eta=eta),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i: (i, 0)),
            pl.BlockSpec((eta, d, db), lambda i: (0, 0, 0)),
            pl.BlockSpec((db,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_m, db), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, db), jnp.float32),
        interpret=interpret,
    )(x.astype(jnp.float32), w.astype(jnp.float32), scale.astype(jnp.float32))


# ---------------------------------------------------------------------------
# autodiff: Pallas forward, jnp backward
# ---------------------------------------------------------------------------
#
# Pallas kernels do not auto-differentiate, so the training path wraps the
# bucket kernel in a custom VJP. The backward pass is a leave-one-out
# product over the eta chained projections — pure GEMM work that XLA maps
# to the MXU directly, so there is nothing to fuse by hand:
#
#   out = scale * prod_j p_j,  p_j = x @ W_j
#   d out / d x = sum_j (g * scale * prod_{l != j} p_l) @ W_j^T
#
# prod_{l != j} is computed with prefix/suffix products (stable at p_j = 0,
# unlike dividing the total product). W is a Rademacher draw (no gradient
# path) and scale is a static constant; both get zero cotangents.


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _rmf_bucket_ad(x, w, scale, block_m, interpret):
    return rmf_bucket(x, w, scale, block_m=block_m, interpret=interpret)


def _rmf_bucket_fwd(x, w, scale, block_m, interpret):
    out = rmf_bucket(x, w, scale, block_m=block_m, interpret=interpret)
    return out, (x, w, scale)


def _rmf_bucket_bwd(block_m, interpret, res, g):
    x, w, scale = res
    eta = w.shape[0]
    if eta == 0:
        return jnp.zeros_like(x), jnp.zeros_like(w), jnp.zeros_like(scale)
    projs = [x @ w[j] for j in range(eta)]  # eta x (M, Db)
    # prefix[j] = prod_{l < j} p_l ; suffix[j] = prod_{l > j} p_l
    prefix = [jnp.ones_like(projs[0])]
    for j in range(1, eta):
        prefix.append(prefix[-1] * projs[j - 1])
    suffix = [jnp.ones_like(projs[0])] * eta
    for j in range(eta - 2, -1, -1):
        suffix[j] = suffix[j + 1] * projs[j + 1]
    gs = g * scale[None, :]
    gx = jnp.zeros_like(x)
    for j in range(eta):
        gx = gx + (gs * prefix[j] * suffix[j]) @ w[j].T
    return gx, jnp.zeros_like(w), jnp.zeros_like(scale)


_rmf_bucket_ad.defvjp(_rmf_bucket_fwd, _rmf_bucket_bwd)


def rmf_features_pallas(x, bucket_omegas, bucket_scales, *, block_m: int = 1024,
                        interpret: bool = True):
    """Full Phi(x) on arbitrary-rank input, bucket-major feature order.

    x: (..., d). Flattens leading dims to rows, runs one kernel launch per
    degree bucket, concatenates, rescales by 1/sqrt(D).
    """
    lead = x.shape[:-1]
    d = x.shape[-1]
    rows = x.reshape(-1, d)
    total = sum(s.shape[0] for s in bucket_scales)
    parts = []
    for (eta, w), scale in zip(bucket_omegas, bucket_scales):
        parts.append(
            _rmf_bucket_ad(rows, w, scale, block_m, interpret)
        )
    phi = jnp.concatenate(parts, axis=-1) * (1.0 / jnp.sqrt(float(total)))
    return phi.reshape(*lead, total)
