"""Pure-jnp oracles for every kernel in the stack.

These are the CORE correctness anchors: the Pallas kernels (rmf.py,
rmfa.py, softmax_attn.py) and the lowered model modules are all tested
against these reference implementations (python/tests/), and the Rust side
re-implements the same math in rust/src/reference/ for cross-language
checks.

Shape conventions
-----------------
  q, k, v          (B, H, n, dh)        attention inputs per head
  omega            (D, max_deg, dh)     Rademacher directions (+-1)
  degrees          (D,) int             per-feature Maclaurin degree (static)
  scales           (D,) f32             sqrt(a_N * p^(N+1)) per feature
  phi_q, phi_k     (B, H, n, D)         random feature maps
  key_mask         (B, n) {0,1}         1 = real token, 0 = padding
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from compile import maclaurin

# ---------------------------------------------------------------------------
# Random Maclaurin Features (Def. 3)
# ---------------------------------------------------------------------------


def rmf_features(x, omega, degrees, scales):
    """phi(x): the RMF map, direct (un-bucketed) evaluation.

    phi_i(x) = sqrt(a_{N_i} p^{N_i+1}) * prod_{j=1..N_i} <omega_{i,j}, x>,
    Phi(x) = sqrt(1/D) [phi_1(x), ..., phi_D(x)].

    O(n * D * max_deg * d) — reference only; the model path uses the
    degree-bucketed formulation (same result, tested equal).
    """
    D, max_deg, _ = omega.shape
    degrees = jnp.asarray(degrees)
    # proj[..., n, i, j] = <omega[i, j], x[..., n, :]>
    proj = jnp.einsum("...nd,ijd->...nij", x, omega)
    # features of degree N use factors j < N; the rest contribute 1.
    live = (jnp.arange(max_deg)[None, :] < degrees[:, None]).astype(x.dtype)
    factors = proj * live + (1.0 - live)
    phi = jnp.prod(factors, axis=-1)  # (..., n, D)
    return phi * jnp.asarray(scales) * (1.0 / np.sqrt(D))


def rmf_features_bucketed(x, bucket_omegas, bucket_scales):
    """phi(x) via static degree buckets (the shape the Pallas kernel uses).

    bucket_omegas: list of (eta, W) with W of shape (eta, dh, D_eta);
    bucket_scales: list of (D_eta,) arrays. Features come out bucket-major
    (a fixed permutation of the direct map — inner products are invariant
    to it as long as q and k share the layout).
    """
    parts = []
    total = sum(s.shape[0] for s in bucket_scales)
    for (eta, W), scale in zip(bucket_omegas, bucket_scales):
        acc = jnp.ones(x.shape[:-1] + (scale.shape[0],), dtype=x.dtype)
        for j in range(eta):
            acc = acc * (x @ W[j].astype(x.dtype))
        parts.append(acc * scale)
    return jnp.concatenate(parts, axis=-1) * (1.0 / np.sqrt(total))


def sample_omega(key, num_features, max_deg, dh, dtype=jnp.float32):
    """Rademacher direction bank, drawn in-graph from a PRNG key."""
    return jax.random.rademacher(key, (num_features, max_deg, dh), dtype=dtype)


# ---------------------------------------------------------------------------
# Attention oracles
# ---------------------------------------------------------------------------


def softmax_attn_ref(q, k, v, key_mask=None, causal=False):
    """Definition 1: exact softmax attention with optional masking."""
    dh = q.shape[-1]
    logits = jnp.einsum("...qd,...kd->...qk", q, k) / np.sqrt(dh)
    logits = _apply_masks(logits, key_mask, causal, neg=True)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("...qk,...kd->...qd", w, v)


def kernelized_attn_ref(q, k, v, kernel, key_mask=None, causal=False, eps=1e-6):
    """Definition 2: exact dot-product-kernelized attention.

    attn_K = sum_i K(Q K_i^T / sqrt(d)) V_i / sum_j K(Q K_j^T / sqrt(d)),
    with masked positions removed from both sums (the paper's M' form).
    """
    dh = q.shape[-1]
    t = jnp.einsum("...qd,...kd->...qk", q, k) / np.sqrt(dh)
    fn = maclaurin.kernel_fn(kernel)
    scores = fn(t)
    scores = _apply_masks(scores, key_mask, causal, neg=False)
    denom = jnp.sum(scores, axis=-1, keepdims=True)
    return jnp.einsum("...qk,...kd->...qd", scores, v) / (denom + eps)


def truncated_kernelized_attn_ref(
    q, k, v, kernel, max_degree, key_mask=None, causal=False, eps=1e-6, p=2.0
):
    """Kernelized attention under the *truncated* Maclaurin expansion.

    This is the exact expectation of the truncated RMF estimator — the
    right oracle for unbiasedness tests of the static-degree lowering
    (degrees are drawn from the renormalized truncated law, so each term's
    effective coefficient is a_N * probs[N] / p^-(N+1)).
    """
    dh = q.shape[-1]
    t = jnp.einsum("...qd,...kd->...qk", q, k) / np.sqrt(dh)
    probs = maclaurin.degree_distribution(p, max_degree)
    scores = jnp.zeros_like(t)
    for n in range(max_degree + 1):
        raw = p ** -(n + 1)
        a = maclaurin.coefficient(kernel, n) * (probs[n] / raw)
        scores = scores + a * t**n
    scores = _apply_masks(scores, key_mask, causal, neg=False)
    denom = jnp.sum(scores, axis=-1, keepdims=True)
    return jnp.einsum("...qk,...kd->...qd", scores, v) / (denom + eps)


def linear_attn_ref(phi_q, phi_k, v, key_mask=None, causal=False, eps=1e-6):
    """RMFA contraction: out = phi_q (phi_k^T v) / (phi_q sum_j phi_k_j).

    The factored form from the paper's RMFA derivation — O(n d D).
    """
    if key_mask is not None:
        phi_k = phi_k * key_mask[:, None, :, None].astype(phi_k.dtype)
    if causal:
        # S_i = sum_{j<=i} phi_k_j (x) v_j, z_i = sum_{j<=i} phi_k_j
        s = jnp.cumsum(jnp.einsum("...nD,...nd->...nDd", phi_k, v), axis=-3)
        z = jnp.cumsum(phi_k, axis=-2)
        num = jnp.einsum("...nD,...nDd->...nd", phi_q, s)
        den = jnp.einsum("...nD,...nD->...n", phi_q, z)
    else:
        s = jnp.einsum("...kD,...kd->...Dd", phi_k, v)
        z = jnp.sum(phi_k, axis=-2)
        num = jnp.einsum("...nD,...Dd->...nd", phi_q, s)
        den = jnp.einsum("...nD,...D->...n", phi_q, z)
    return num / (den[..., None] + eps)


def rmfa_ref(q, k, v, omega, degrees, scales, key_mask=None, causal=False, eps=1e-6):
    """Full RMFA oracle: RMF maps on Q/d^(1/4), K/d^(1/4) + linear attn."""
    dh = q.shape[-1]
    root = dh**0.25
    phi_q = rmf_features(q / root, omega, degrees, scales)
    phi_k = rmf_features(k / root, omega, degrees, scales)
    return linear_attn_ref(phi_q, phi_k, v, key_mask=key_mask, causal=causal, eps=eps)


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _apply_masks(scores, key_mask, causal, neg):
    """Mask attention scores; `neg` selects -1e9 (logits) vs 0 (kernel)."""
    fill = -1e9 if neg else 0.0
    if key_mask is not None:
        m = key_mask[:, None, None, :].astype(bool)
        scores = jnp.where(m, scores, fill)
    if causal:
        n, m_ = scores.shape[-2], scores.shape[-1]
        tri = jnp.tril(jnp.ones((n, m_), dtype=bool))
        scores = jnp.where(tri, scores, fill)
    return scores
