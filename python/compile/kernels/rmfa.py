"""Pallas kernels: the RMFA linear-attention contraction (Layer 1).

Given feature maps phi_q, phi_k in R^(G x n x D) (G = batch*heads rows of
independent attention problems) and values v in R^(G x n x d), compute

    out_i = phi_q_i . S / (phi_q_i . z + eps),
    S = sum_j phi_k_j (x) v_j,   z = sum_j phi_k_j            (bidirectional)
    S_i, z_i = prefix sums over j <= i                        (causal)

This is the factored O(n d D) path from the paper's RMFA derivation; it
never materializes the (n x n) score matrix.

TPU mapping (DESIGN.md §Hardware-Adaptation):
  * bidirectional = two passes. Pass 1 streams K/V blocks HBM->VMEM and
    accumulates the (D, d+1) state in VMEM (value state + normalizer column
    fused into one accumulator so a single MXU contraction serves both).
    Pass 2 streams Q blocks and applies the state: one (bm,D)x(D,d+1) GEMM.
  * causal = chunked prefix scan (the flash-linear-attention schedule):
    per block, inter-block term comes from the carried (D, d+1) state and
    the intra-block term from a tril-masked (bm x bm) score block.

VMEM for defaults (bm=128, D=128, d=32): state 128*33*4 ~= 17 KB, blocks
128*128*4 + 128*33*4 ~= 82 KB — comfortably under a TPU core's ~16 MB.

interpret=True on this image (see rmf.py docstring).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_HIGH = jax.lax.Precision.HIGHEST


# ---------------------------------------------------------------------------
# bidirectional: pass 1 — KV state accumulation
# ---------------------------------------------------------------------------


def _kv_state_kernel(phi_k_ref, v_ref, o_ref, *, nb: int):
    """Grid (G, nb): accumulate S = phi_k^T [v | 1] into o_ref (D, d+1).

    The same output block is revisited across the nb axis; we initialize on
    the first visit and accumulate afterwards (sequential grid semantics).
    Masked (padding) keys must be zeroed in phi_k by the caller — that
    removes them from both S and z, which is exactly the paper's M' form.
    """
    j = pl.program_id(1)
    phi_k = phi_k_ref[0]  # (bn, D)
    v = v_ref[0]  # (bn, d)
    ones = jnp.ones((v.shape[0], 1), dtype=v.dtype)
    vv = jnp.concatenate([v, ones], axis=-1)  # (bn, d+1)
    upd = jnp.dot(phi_k.T, vv, precision=_HIGH)  # (D, d+1)

    @pl.when(j == 0)
    def _init():
        o_ref[0] = upd

    @pl.when(j > 0)
    def _acc():
        o_ref[0] += upd


def _apply_state_kernel(phi_q_ref, s_ref, o_ref, *, eps: float):
    """Grid (G, nb): out = phi_q S[:, :d] / (phi_q S[:, d] + eps)."""
    phi_q = phi_q_ref[0]  # (bn, D)
    s = s_ref[0]  # (D, d+1)
    fused = jnp.dot(phi_q, s, precision=_HIGH)  # (bn, d+1)
    num = fused[:, :-1]
    den = fused[:, -1:]
    o_ref[0] = num / (den + eps)


def _linear_attn_bidir_impl(phi_q, phi_k, v, *, eps: float = 1e-6,
                            block_n: int = 128, interpret: bool = True):
    """Bidirectional RMFA contraction.

    Args:
      phi_q, phi_k: (G, n, D) feature maps (phi_k already key-masked).
      v:            (G, n, d) values.
    Returns: (G, n, d).
    """
    g, n, D = phi_q.shape
    d = v.shape[-1]
    bn = min(block_n, n)
    assert n % bn == 0, f"seq len {n} not divisible by block {bn}"
    nb = n // bn

    state = pl.pallas_call(
        functools.partial(_kv_state_kernel, nb=nb),
        grid=(g, nb),
        in_specs=[
            pl.BlockSpec((1, bn, D), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bn, d), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, D, d + 1), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((g, D, d + 1), jnp.float32),
        interpret=interpret,
    )(phi_k.astype(jnp.float32), v.astype(jnp.float32))

    return pl.pallas_call(
        functools.partial(_apply_state_kernel, eps=eps),
        grid=(g, nb),
        in_specs=[
            pl.BlockSpec((1, bn, D), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, D, d + 1), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((g, n, d), jnp.float32),
        interpret=interpret,
    )(phi_q.astype(jnp.float32), state)


# ---------------------------------------------------------------------------
# causal: chunked prefix scan
# ---------------------------------------------------------------------------


def _causal_kernel(phi_q_ref, phi_k_ref, v_ref, o_ref, *, nb: int, bn: int,
                   eps: float):
    """Grid (G,): one attention problem per program, fori over chunks.

    Carry is the functional (D, d+1) prefix state; each chunk combines the
    inter-chunk contribution (state GEMM) with the intra-chunk one
    (tril-masked score block), then advances the state.
    """
    D = phi_q_ref.shape[-1]
    d = v_ref.shape[-1]
    tril = jnp.tril(jnp.ones((bn, bn), dtype=jnp.float32))

    def body(c, state):
        sl = (0, pl.dslice(c * bn, bn), slice(None))
        pq = pl.load(phi_q_ref, sl)  # (bn, D)
        pk = pl.load(phi_k_ref, sl)  # (bn, D)
        vv = pl.load(v_ref, sl)  # (bn, d)
        # inter-chunk: everything strictly before this chunk
        fused = jnp.dot(pq, state, precision=_HIGH)  # (bn, d+1)
        # intra-chunk: tril-masked scores within the chunk
        scores = jnp.dot(pq, pk.T, precision=_HIGH) * tril  # (bn, bn)
        num = fused[:, :d] + jnp.dot(scores, vv, precision=_HIGH)
        den = fused[:, d:] + jnp.sum(scores, axis=-1, keepdims=True)
        pl.store(o_ref, sl, num / (den + eps))
        ones = jnp.ones((bn, 1), dtype=vv.dtype)
        upd = jnp.dot(pk.T, jnp.concatenate([vv, ones], -1), precision=_HIGH)
        return state + upd

    init = jnp.zeros((D, d + 1), dtype=jnp.float32)
    jax.lax.fori_loop(0, nb, body, init)


def _linear_attn_causal_impl(phi_q, phi_k, v, *, eps: float = 1e-6,
                             block_n: int = 64, interpret: bool = True):
    """Causal RMFA contraction (decoder / autoregressive masking).

    Args/returns as linear_attn_bidir; out_i only attends to j <= i.
    """
    g, n, D = phi_q.shape
    d = v.shape[-1]
    bn = min(block_n, n)
    assert n % bn == 0, f"seq len {n} not divisible by block {bn}"
    nb = n // bn
    return pl.pallas_call(
        functools.partial(_causal_kernel, nb=nb, bn=bn, eps=eps),
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, n, D), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, D), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, n, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((g, n, d), jnp.float32),
        interpret=interpret,
    )(phi_q.astype(jnp.float32), phi_k.astype(jnp.float32),
      v.astype(jnp.float32))


# ---------------------------------------------------------------------------
# autodiff: Pallas forward, jnp backward
# ---------------------------------------------------------------------------
#
# Pallas kernels do not auto-differentiate; the training path wraps the
# contractions in custom VJPs. The backward passes are pure GEMM chains
# (XLA maps them straight to the MXU), derived from out = num/den:
#
#   fused = phi_q @ S,  S = phi_k^T [v | 1],  num = fused[:, :d],
#   den = fused[:, d] + eps,  out = num / den
#   g_num = g / den                  g_den = -sum(g * out_pre) / den
#   d phi_q = [g_num | g_den] @ S^T
#   d S     = phi_q^T @ [g_num | g_den]
#   d phi_k = [v | 1] @ dS^T         d v = phi_k @ dS[:, :d]
#
# The causal variant replaces S with per-position prefix states; gradients
# use a forward cumsum for dphi_q and a *reverse* cumsum for dphi_k / dv.
# Causal is only used at toy scale (translation, n <= 128), so the (n, D,
# d+1) cumsum materialization in the backward is cheap.


def _bidir_fused(phi_q, phi_k, v, eps):
    ones = jnp.ones(v.shape[:-1] + (1,), v.dtype)
    vv = jnp.concatenate([v, ones], axis=-1)
    s = jnp.einsum("gkD,gke->gDe", phi_k, vv)
    fused = jnp.einsum("gnD,gDe->gne", phi_q, s)
    num, den = fused[..., :-1], fused[..., -1:] + eps
    return num / den, (s, num, den)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def linear_attn_bidir(phi_q, phi_k, v, eps=1e-6, block_n=128, interpret=True):
    """Bidirectional RMFA contraction (differentiable).

    phi_q, phi_k: (G, n, D) feature maps (phi_k already key-masked);
    v: (G, n, d). Returns (G, n, d). Forward = Pallas streaming kernels,
    backward = jnp GEMMs (see module comment).
    """
    return _linear_attn_bidir_impl(
        phi_q, phi_k, v, eps=eps, block_n=block_n, interpret=interpret
    )


def _bidir_fwd(phi_q, phi_k, v, eps, block_n, interpret):
    out = _linear_attn_bidir_impl(
        phi_q, phi_k, v, eps=eps, block_n=block_n, interpret=interpret
    )
    return out, (phi_q, phi_k, v)


def _bidir_bwd(eps, block_n, interpret, res, g):
    phi_q, phi_k, v = res
    out, (s, num, den) = _bidir_fused(phi_q, phi_k, v, eps)
    g_num = g / den
    g_den = -jnp.sum(g * out, axis=-1, keepdims=True) / den
    gf = jnp.concatenate([g_num, g_den], axis=-1)  # (G, n, d+1)
    d_phi_q = jnp.einsum("gne,gDe->gnD", gf, s)
    d_s = jnp.einsum("gnD,gne->gDe", phi_q, gf)
    ones = jnp.ones(v.shape[:-1] + (1,), v.dtype)
    vv = jnp.concatenate([v, ones], axis=-1)
    d_phi_k = jnp.einsum("gke,gDe->gkD", vv, d_s)
    d_v = jnp.einsum("gkD,gDe->gke", phi_k, d_s[..., :-1])
    return d_phi_q, d_phi_k, d_v


linear_attn_bidir.defvjp(_bidir_fwd, _bidir_bwd)


def _causal_states(phi_k, v):
    ones = jnp.ones(v.shape[:-1] + (1,), v.dtype)
    vv = jnp.concatenate([v, ones], axis=-1)
    upd = jnp.einsum("gnD,gne->gnDe", phi_k, vv)
    return jnp.cumsum(upd, axis=1), vv  # (G, n, D, d+1)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def linear_attn_causal(phi_q, phi_k, v, eps=1e-6, block_n=64, interpret=True):
    """Causal RMFA contraction (differentiable); see linear_attn_bidir."""
    return _linear_attn_causal_impl(
        phi_q, phi_k, v, eps=eps, block_n=block_n, interpret=interpret
    )


def _causal_fwd(phi_q, phi_k, v, eps, block_n, interpret):
    out = _linear_attn_causal_impl(
        phi_q, phi_k, v, eps=eps, block_n=block_n, interpret=interpret
    )
    return out, (phi_q, phi_k, v)


def _causal_bwd(eps, block_n, interpret, res, g):
    phi_q, phi_k, v = res
    states, vv = _causal_states(phi_k, v)  # (G, n, D, d+1)
    fused = jnp.einsum("gnD,gnDe->gne", phi_q, states)
    num, den = fused[..., :-1], fused[..., -1:] + eps
    out = num / den
    g_num = g / den
    g_den = -jnp.sum(g * out, axis=-1, keepdims=True) / den
    gf = jnp.concatenate([g_num, g_den], axis=-1)  # (G, n, d+1)
    d_phi_q = jnp.einsum("gne,gnDe->gnD", gf, states)
    # d states_i = phi_q_i (x) gf_i; position j receives sum_{i >= j}
    d_state = jnp.einsum("gnD,gne->gnDe", phi_q, gf)
    rev = jnp.flip(jnp.cumsum(jnp.flip(d_state, axis=1), axis=1), axis=1)
    d_phi_k = jnp.einsum("gne,gnDe->gnD", vv, rev)
    d_v = jnp.einsum("gnD,gnDe->gne", phi_k, rev)[..., :-1]
    return d_phi_q, d_phi_k, d_v


linear_attn_causal.defvjp(_causal_fwd, _causal_bwd)
