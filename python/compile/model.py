"""Layer 2: the Macformer model family in JAX.

One shared transformer trunk with a pluggable attention contraction:

  * ``softmax``  — exact softmax attention (the base Transformer of
                   Table 2), via the Pallas online-softmax kernel.
  * ``rfa``      — Random Feature Attention baseline (Peng et al. 2021):
                   trigonometric random Fourier features on l2-scaled
                   Q/K + the linear-attention contraction.
  * ``mac_exp | mac_inv | mac_log | mac_trigh | mac_sqrt`` — Macformer:
                   Random Maclaurin Features for the Table-1 kernel +
                   the same linear-attention contraction, wrapped in
                   ppSBN (Algorithm 1).

Task heads: sequence classification (LRA Text / Listops), dual-encoder
retrieval (LRA Retrieval), and a causal LM head (the Fig-3 translation
toy, decoder-only over [src SEP tgt] with loss on the target span).

Everything is a pure function of (params pytree, int32 token batch,
PRNG key); `python/compile/aot.py` lowers init/train/eval/generate
wrappers of these functions to HLO text for the Rust coordinator.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from compile import maclaurin, ppsbn
from compile.kernels import ref as kref
from compile.kernels import rmf as krmf
from compile.kernels import rmfa as krmfa
from compile.kernels import softmax_attn as ksoftmax

ATTN_VARIANTS = (
    "softmax", "rfa", "mac_exp", "mac_inv", "mac_log", "mac_trigh", "mac_sqrt",
)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Hyperparameters; paper defaults from the LRA section."""

    vocab_size: int = 260
    d_model: int = 64
    d_ff: int = 128
    n_layers: int = 2
    n_heads: int = 2
    seq_len: int = 1024
    num_classes: int = 2
    attn: str = "softmax"
    feature_dim: int = 128  # D, the random projection dimension
    p: float = 2.0  # RMF degree-law hyperparameter
    max_degree: int = maclaurin.DEFAULT_MAX_DEGREE
    ppsbn: bool = True  # pre/post SBN around the contraction
    ppsbn_eps: float = 1e-13
    ppsbn_norm_mode: str = "max_row"
    causal: bool = False
    task: str = "cls"  # cls | retrieval | lm
    use_pallas: bool = True  # L1 kernels vs pure-jnp ref (ablation)
    rmf_seed: int = 17  # static degree draw
    redraw: bool = True  # redraw omega each step vs fixed per-init
    dropout: float = 0.0  # reserved; kept 0 for deterministic HLO
    attn_block_n: int = 256  # raised 128 -> 256 in the §Perf pass
    eps: float = 1e-6

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def kernel_name(self) -> Optional[str]:
        return self.attn[4:] if self.attn.startswith("mac_") else None

    def validate(self) -> "ModelConfig":
        if self.attn not in ATTN_VARIANTS:
            raise ValueError(f"unknown attn {self.attn!r}")
        if self.task not in ("cls", "retrieval", "lm"):
            raise ValueError(f"unknown task {self.task!r}")
        if self.attn == "rfa" and self.feature_dim % 2:
            raise ValueError("rfa needs an even feature_dim (sin|cos halves)")
        return self


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------


def _dense_init(key, d_in, d_out):
    w = jax.random.normal(key, (d_in, d_out), jnp.float32)
    return {"w": w * np.sqrt(1.0 / d_in), "b": jnp.zeros((d_out,), jnp.float32)}


def _ln_init(d):
    return {"g": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    """Initialize the full parameter pytree for `cfg`."""
    cfg.validate()
    keys = jax.random.split(key, 4 + cfg.n_layers)
    params: Dict[str, Any] = {
        "tok_emb": jax.random.normal(
            keys[0], (cfg.vocab_size, cfg.d_model), jnp.float32
        ) * 0.02,
        "pos_emb": jax.random.normal(
            keys[1], (cfg.seq_len, cfg.d_model), jnp.float32
        ) * 0.02,
        "layers": [],
        "ln_f": _ln_init(cfg.d_model),
    }
    for i in range(cfg.n_layers):
        lk = jax.random.split(keys[4 + i], 8)
        layer = {
            "ln1": _ln_init(cfg.d_model),
            "ln2": _ln_init(cfg.d_model),
            "wq": _dense_init(lk[0], cfg.d_model, cfg.d_model),
            "wk": _dense_init(lk[1], cfg.d_model, cfg.d_model),
            "wv": _dense_init(lk[2], cfg.d_model, cfg.d_model),
            "wo": _dense_init(lk[3], cfg.d_model, cfg.d_model),
            "ff1": _dense_init(lk[4], cfg.d_model, cfg.d_ff),
            "ff2": _dense_init(lk[5], cfg.d_ff, cfg.d_model),
        }
        if cfg.ppsbn:
            # postSBN trainable scale/exponent, identity at init (Thm 3's
            # t and r are fitted by these during training).
            layer["sbn_gamma"] = jnp.ones((cfg.n_heads, 1, 1), jnp.float32)
            layer["sbn_beta"] = jnp.ones((cfg.n_heads, 1, 1), jnp.float32)
        if cfg.attn == "rfa":
            # RFA draws w ~ N(0, I) at init (fixed bank; redraw handled by
            # the in-graph key when cfg.redraw).
            layer["rfa_w"] = jax.random.normal(
                lk[6], (cfg.feature_dim // 2, cfg.d_head), jnp.float32
            )
        params["layers"].append(layer)
    if cfg.task == "cls":
        params["head"] = _dense_init(keys[2], cfg.d_model, cfg.num_classes)
    elif cfg.task == "retrieval":
        hk = jax.random.split(keys[2], 2)
        params["head_mlp"] = _dense_init(hk[0], 4 * cfg.d_model, cfg.d_model)
        params["head"] = _dense_init(hk[1], cfg.d_model, cfg.num_classes)
    else:  # lm
        params["head"] = _dense_init(keys[2], cfg.d_model, cfg.vocab_size)
    return params


# ---------------------------------------------------------------------------
# static RMF plan (degrees are drawn at lowering time — DESIGN.md)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RmfPlan:
    """Static degree bucketing shared by all layers of one model."""

    degrees: Tuple[int, ...]
    bucket_etas: Tuple[int, ...]
    bucket_sizes: Tuple[int, ...]
    bucket_scales: Tuple[Tuple[float, ...], ...]

    @property
    def max_eta(self) -> int:
        return max(self.bucket_etas)


def make_rmf_plan(cfg: ModelConfig) -> RmfPlan:
    kernel = cfg.kernel_name
    assert kernel is not None
    degrees = maclaurin.sample_degrees(
        cfg.feature_dim, cfg.p, cfg.max_degree, seed=cfg.rmf_seed
    )
    buckets = maclaurin.degree_buckets(degrees)
    scales = maclaurin.feature_scales(kernel, degrees, cfg.p)
    etas, sizes, bscales = [], [], []
    for eta, idx in sorted(buckets.items()):
        etas.append(int(eta))
        sizes.append(len(idx))
        bscales.append(tuple(float(s) for s in scales[idx]))
    return RmfPlan(
        degrees=tuple(int(d) for d in degrees),
        bucket_etas=tuple(etas),
        bucket_sizes=tuple(sizes),
        bucket_scales=tuple(bscales),
    )


def _draw_bucket_omegas(key, plan: RmfPlan, dh: int):
    """In-graph Rademacher direction draw, one bank per degree bucket."""
    out = []
    keys = jax.random.split(key, len(plan.bucket_etas))
    for bk, eta, size in zip(keys, plan.bucket_etas, plan.bucket_sizes):
        if eta == 0:
            w = jnp.zeros((0, dh, size), jnp.float32)
        else:
            w = jax.random.rademacher(bk, (eta, dh, size), jnp.float32)
        out.append((eta, w))
    return out


# ---------------------------------------------------------------------------
# attention contractions
# ---------------------------------------------------------------------------


def _heads(x, cfg):
    b, n, _ = x.shape
    return x.reshape(b, n, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)


def _unheads(x, cfg):
    b, h, n, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, n, h * dh)


def _flatten_gh(x):
    b, h, n, d = x.shape
    return x.reshape(b * h, n, d)


def _unflatten_gh(x, b, h):
    g, n, d = x.shape
    return x.reshape(b, h, n, d)


def _rmf_phi(x, plan: RmfPlan, omegas, cfg, interpret=True):
    """Phi(x / d^(1/4)) for (B, H, n, dh) input -> (B, H, n, D)."""
    x = x / (cfg.d_head**0.25)
    bscales = [jnp.asarray(s, jnp.float32) for s in plan.bucket_scales]
    if cfg.use_pallas:
        return krmf.rmf_features_pallas(x, omegas, bscales, interpret=interpret)
    return kref.rmf_features_bucketed(x, omegas, bscales)


def _rfa_phi(x, w, cfg):
    """RFA trigonometric features on per-row l2-normalized inputs.

    phi(x) = sqrt(2/D) [sin(w x), cos(w x)] — the Peng et al. (2021) map
    for the Gaussian kernel; with unit-norm rows, softmax similarity is a
    fixed monotone transform of the Gaussian kernel.
    """
    norm = jnp.sqrt(jnp.sum(x * x, axis=-1, keepdims=True) + 1e-6)
    xn = x / norm
    proj = jnp.einsum("...nd,fd->...nf", xn, w)
    d_half = w.shape[0]
    return jnp.concatenate(
        [jnp.sin(proj), jnp.cos(proj)], axis=-1
    ) * np.sqrt(1.0 / d_half)


def _linear_contract(phi_q, phi_k, v, key_mask, cfg):
    """Dispatch the linear-attention contraction (Pallas or ref)."""
    b, h = phi_q.shape[0], phi_q.shape[1]
    if key_mask is not None:
        phi_k = phi_k * key_mask[:, None, :, None].astype(phi_k.dtype)
    if not cfg.use_pallas:
        return kref.linear_attn_ref(
            phi_q, phi_k, v, key_mask=None, causal=cfg.causal, eps=cfg.eps
        )
    fq, fk, fv = _flatten_gh(phi_q), _flatten_gh(phi_k), _flatten_gh(v)
    if cfg.causal:
        out = krmfa.linear_attn_causal(
            fq, fk, fv, cfg.eps, min(cfg.attn_block_n, 64), True
        )
    else:
        out = krmfa.linear_attn_bidir(
            fq, fk, fv, cfg.eps, cfg.attn_block_n, True
        )
    return _unflatten_gh(out, b, h)


def attention(layer, x, key_mask, rng_key, cfg: ModelConfig,
              plan: Optional[RmfPlan]):
    """One multi-head attention block body (pre-LN residual trunk)."""
    b, n, _ = x.shape
    q = _heads(x @ layer["wq"]["w"] + layer["wq"]["b"], cfg)
    k = _heads(x @ layer["wk"]["w"] + layer["wk"]["b"], cfg)
    v = _heads(x @ layer["wv"]["w"] + layer["wv"]["b"], cfg)

    if cfg.attn == "softmax":
        # Fig-3 configuration: ppSBN wrapped around the *traditional*
        # softmax attention ("incorporated the ppSBN mechanism before and
        # after the attention layer" on the base Transformer).
        if cfg.ppsbn:
            q = ppsbn.pre_sbn(q, eps=cfg.ppsbn_eps,
                              norm_mode=cfg.ppsbn_norm_mode,
                              key_mask=key_mask)
            k = ppsbn.pre_sbn(k, eps=cfg.ppsbn_eps,
                              norm_mode=cfg.ppsbn_norm_mode,
                              key_mask=key_mask)
        if cfg.use_pallas:
            bias = None
            if key_mask is not None:
                # (B, n) -> (B*H, n), head-major to match _flatten_gh
                bias = jnp.broadcast_to(
                    ((1.0 - key_mask.astype(jnp.float32)) * -1e9)[:, None, :],
                    (b, cfg.n_heads, n),
                ).reshape(b * cfg.n_heads, n)
            out = ksoftmax.softmax_attn(
                _flatten_gh(q), _flatten_gh(k), _flatten_gh(v), bias,
                cfg.causal, min(cfg.attn_block_n, n),
                min(cfg.attn_block_n, n), True,
            )
            out = _unflatten_gh(out, b, cfg.n_heads)
        else:
            out = kref.softmax_attn_ref(q, k, v, key_mask=key_mask,
                                        causal=cfg.causal)
        if cfg.ppsbn:
            out = ppsbn.post_sbn(out, layer["sbn_gamma"], layer["sbn_beta"])
        return _unheads(out, cfg) @ layer["wo"]["w"] + layer["wo"]["b"]

    # linear-feature variants: optional preSBN, feature map, contraction,
    # optional postSBN.
    if cfg.ppsbn:
        q = ppsbn.pre_sbn(q, eps=cfg.ppsbn_eps, norm_mode=cfg.ppsbn_norm_mode,
                          key_mask=key_mask)
        k = ppsbn.pre_sbn(k, eps=cfg.ppsbn_eps, norm_mode=cfg.ppsbn_norm_mode,
                          key_mask=key_mask)

    if cfg.attn == "rfa":
        w = layer["rfa_w"]
        if cfg.redraw:
            w = jax.random.normal(
                rng_key, (cfg.feature_dim // 2, cfg.d_head), jnp.float32
            )
        phi_q = _rfa_phi(q, w, cfg)
        phi_k = _rfa_phi(k, w, cfg)
    else:
        assert plan is not None
        omegas = _draw_bucket_omegas(rng_key, plan, cfg.d_head)
        phi_q = _rmf_phi(q, plan, omegas, cfg)
        phi_k = _rmf_phi(k, plan, omegas, cfg)

    out = _linear_contract(phi_q, phi_k, v, key_mask, cfg)
    if cfg.ppsbn:
        out = ppsbn.post_sbn(out, layer["sbn_gamma"], layer["sbn_beta"])
    return _unheads(out, cfg) @ layer["wo"]["w"] + layer["wo"]["b"]


# ---------------------------------------------------------------------------
# trunk + heads
# ---------------------------------------------------------------------------


def _layer_norm(x, p):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-6) * p["g"] + p["b"]


def _ffn(layer, x):
    h = jax.nn.relu(x @ layer["ff1"]["w"] + layer["ff1"]["b"])
    return h @ layer["ff2"]["w"] + layer["ff2"]["b"]


def encode(params, tokens, key_mask, rng_key, cfg: ModelConfig,
           plan: Optional[RmfPlan]):
    """Token ids (B, n) -> contextual states (B, n, d_model)."""
    b, n = tokens.shape
    x = params["tok_emb"][tokens] + params["pos_emb"][None, :n, :]
    keys = jax.random.split(rng_key, cfg.n_layers)
    for layer, lk in zip(params["layers"], keys):
        x = x + attention(layer, _layer_norm(x, layer["ln1"]), key_mask, lk,
                          cfg, plan)
        x = x + _ffn(layer, _layer_norm(x, layer["ln2"]))
    return _layer_norm(x, params["ln_f"])


def _pool(x, key_mask):
    if key_mask is None:
        return jnp.mean(x, axis=1)
    m = key_mask[:, :, None].astype(x.dtype)
    return jnp.sum(x * m, axis=1) / (jnp.sum(m, axis=1) + 1e-6)


def cls_logits(params, tokens, key_mask, rng_key, cfg, plan):
    """Classification head (LRA Text / Listops): mean-pool -> dense."""
    x = encode(params, tokens, key_mask, rng_key, cfg, plan)
    pooled = _pool(x, key_mask)
    return pooled @ params["head"]["w"] + params["head"]["b"]


def retrieval_logits(params, tok1, mask1, tok2, mask2, rng_key, cfg, plan):
    """Dual-encoder head (LRA Retrieval): shared trunk, concat features."""
    k1, k2 = jax.random.split(rng_key)
    e1 = _pool(encode(params, tok1, mask1, k1, cfg, plan), mask1)
    e2 = _pool(encode(params, tok2, mask2, k2, cfg, plan), mask2)
    feats = jnp.concatenate([e1, e2, jnp.abs(e1 - e2), e1 * e2], axis=-1)
    h = jax.nn.relu(feats @ params["head_mlp"]["w"] + params["head_mlp"]["b"])
    return h @ params["head"]["w"] + params["head"]["b"]


def lm_logits(params, tokens, rng_key, cfg, plan):
    """Causal LM head (Fig-3 translation toy): next-token logits."""
    x = encode(params, tokens, None, rng_key, cfg, plan)
    return x @ params["head"]["w"] + params["head"]["b"]


def count_params(params) -> int:
    return int(sum(np.prod(p.shape) for p in jax.tree_util.tree_leaves(params)))
