"""pre-post Scaling Batch Normalization (Algorithm 1).

Two-stage regularization wrapped around the attention contraction:

  preSBN  — batch-normalize Q and K per channel, then l2-scale so every
            query/key row lies in the unit ball l2(0,1). This is the
            regime where (a) Schoenberg's theorem makes the RMF estimator
            unbiased (Thm 1) and (b) the inv/log/sqrt kernels' Maclaurin
            domains (|t| <= 1) are valid.
  postSBN — rescale the attention output with trainable (gamma, beta):
            att <- (gamma * att)^beta, fitting the (t, r) scale factors of
            Theorem 3 so the pre-stage shrinkage is undone in distribution.

Paper ambiguity, resolved here and validated by tests: Algorithm 1 writes
`Q <- Q / ||Q||_2` with matrix Q. Dividing by the *Frobenius* norm makes
every row's norm <= 1 but shrinks rows to O(1/sqrt(n)), collapsing the
kernelized scores toward a constant; dividing by the *max row norm* also
guarantees rows in l2(0,1) (the theorem's actual requirement) with the
least shrinkage, so that is the default. `norm_mode` keeps both plus a
per-row option for ablations (bench: table2 ablation flag).

postSBN on possibly-negative attention outputs (non-exp kernels can yield
negative combinations — see Definition 2 discussion) uses the odd power
extension sign(x)*|gamma*x|^beta so the map stays real and monotone.
"""

from __future__ import annotations

import jax.numpy as jnp

NORM_MODES = ("max_row", "fro", "row")


def pre_sbn(x, eps: float = 1e-13, norm_mode: str = "max_row", key_mask=None):
    """Stage 1 of Algorithm 1 for one of Q or K.

    x: (B, H, n, dh). Batch-norm statistics are taken over (batch, seq)
    per (head, channel) — the BN axes of the baseline implementation —
    then rows are scaled into the l2 unit ball.

    key_mask (B, n) restricts the statistics to real tokens: Algorithm 1
    is silent on padding, but unmasked BN statistics would leak padded
    positions into every output (caught by
    test_model.py::test_padding_mask_blocks_information).
    """
    if key_mask is not None:
        m = key_mask[:, None, :, None].astype(x.dtype)  # (B, 1, n, 1)
        count = jnp.sum(m, axis=(0, 2), keepdims=True) * jnp.ones_like(
            x[:1, :, :1, :]
        )
        mu = jnp.sum(x * m, axis=(0, 2), keepdims=True) / (count + eps)
        var = jnp.sum(((x - mu) ** 2) * m, axis=(0, 2), keepdims=True) / (
            count + eps
        )
        x = (x - mu) / jnp.sqrt(var + eps) * m
    else:
        mu = jnp.mean(x, axis=(0, 2), keepdims=True)
        var = jnp.var(x, axis=(0, 2), keepdims=True)
        x = (x - mu) / jnp.sqrt(var + eps)
    # NOTE the 1e-12 inside every sqrt: masked rows are exactly zero and
    # d sqrt(u)/du -> inf at u = 0, which poisons the whole layer's
    # gradient with 0 * inf = NaN (caught by the lra_text train run).
    if norm_mode == "fro":
        # ||X||_F per (B, H) matrix, the literal Algorithm-1 reading.
        denom = jnp.sqrt(jnp.sum(x * x, axis=(-2, -1), keepdims=True) + 1e-12)
    elif norm_mode == "max_row":
        # max_i ||x_i||_2 per (B, H): tightest scalar scaling that still
        # puts every row in l2(0,1).
        row = jnp.sqrt(jnp.sum(x * x, axis=-1, keepdims=True) + 1e-12)
        denom = jnp.max(row, axis=-2, keepdims=True)
    elif norm_mode == "row":
        # per-row unit normalization (rows on the sphere, not just ball).
        denom = jnp.sqrt(jnp.sum(x * x, axis=-1, keepdims=True) + 1e-12)
    else:
        raise ValueError(f"norm_mode must be one of {NORM_MODES}")
    return x / (denom + eps)


def post_sbn(att, gamma, beta):
    """Stage 2 of Algorithm 1: att <- sign(g*att) * |gamma * att|^beta.

    gamma, beta: trainable scalars (broadcastable to att); initialized to 1
    so the layer starts as identity.
    """
    scaled = gamma * att
    return jnp.sign(scaled) * jnp.power(jnp.abs(scaled) + 1e-12, beta)
