"""Experiment registry: the (task x variant) grid the paper evaluates.

Single source of truth shared by aot.py (what to lower), the pytest suite
(what to check), and — through artifacts/manifest.json — the Rust
coordinator (what to run).

Sizing notes (DESIGN.md §Substitutions): the paper trains LRA Text at
n=4096 and Listops at n=2048 on an RTX A6000; this testbed is a CPU PJRT
client running interpret-lowered Pallas, so the default grid uses n=1024/
512/512. The *normalized* Table-2 quantities (time and memory relative to
the base Transformer, accuracy ordering) are preserved because every
variant shares the same n. `--full` lowers the paper-scale grid too.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from compile import model as M
from compile import train as T

#: Table-2 attention variants (paper order).
VARIANTS = ("softmax", "rfa", "mac_exp", "mac_inv", "mac_trigh", "mac_log",
            "mac_sqrt")

# Fig-3 translation layout: [src (padded to SRC_MAX) | SEP | tgt | EOS pad]
TRANS_SRC_MAX = 24
TRANS_TGT_MAX = 32
TRANS_SEQ = 64
TRANS_PROMPT_LEN = TRANS_SRC_MAX + 1  # first target position


@dataclasses.dataclass(frozen=True)
class TaskSpec:
    name: str
    task: str  # cls | retrieval | lm
    seq_len: int
    vocab_size: int
    num_classes: int
    batch: int
    causal: bool = False


TASKS: Dict[str, TaskSpec] = {
    "lra_text": TaskSpec("lra_text", "cls", 1024, 260, 2, 16),
    "lra_listops": TaskSpec("lra_listops", "cls", 512, 32, 10, 32),
    "lra_retrieval": TaskSpec("lra_retrieval", "retrieval", 512, 260, 2, 16),
    "translation": TaskSpec(
        "translation", "lm", TRANS_SEQ, 512, 0, 32, causal=True
    ),
}

#: Fig-4 micro-benchmark grid (paper: b=16, h=8, d=64, n in 200..4000,
#: D = powers of two). G = b*h attention problems per module.
MICRO_B, MICRO_H, MICRO_D = 16, 8, 64
MICRO_LENGTHS = (256, 512, 1024, 2048, 4096)
MICRO_FEATURES = (64, 128, 256)
MICRO_EPS = 1e-12  # preSBN eps for the simulation (paper: 1e-12)


def model_config(task: TaskSpec, variant: str,
                 ppsbn: Optional[bool] = None) -> M.ModelConfig:
    """The paper's LRA hyperparameters for one (task, variant) cell.

    ppSBN defaults: ON for Macformer variants (it is part of the
    architecture), OFF for the softmax/RFA baselines — except the Fig-3
    ablation which passes ppsbn explicitly.
    """
    if ppsbn is None:
        ppsbn = variant.startswith("mac_")
    return M.ModelConfig(
        vocab_size=task.vocab_size,
        d_model=64,
        d_ff=128,
        n_layers=2,
        n_heads=2,
        seq_len=task.seq_len,
        num_classes=max(task.num_classes, 1),
        attn=variant,
        feature_dim=128,
        p=2.0,
        ppsbn=ppsbn,
        ppsbn_eps=1e-13,
        causal=task.causal,
        task=task.task,
        use_pallas=True,
    ).validate()


def opt_config(task: TaskSpec) -> T.OptConfig:
    # Paper: 1000 steps of initialization (we map this to LR warmup) and
    # 10000 steps of optimization.
    return T.OptConfig(lr=1e-3, warmup_steps=1000)


def grid() -> Tuple[Tuple[str, str], ...]:
    """All Table-2 cells: (task, variant)."""
    return tuple(
        (t, v)
        for t in ("lra_text", "lra_listops", "lra_retrieval")
        for v in VARIANTS
    )


def fig3_cells() -> Tuple[Tuple[str, str, bool], ...]:
    """Fig-3 cells: (task, variant, ppsbn) on the translation toy."""
    return (("translation", "softmax", False), ("translation", "softmax", True))
