"""Layer 2: optimizer and train/eval/generate step functions.

Adam is implemented from scratch (no optax dependency on the AOT path) so
the whole training step lowers to one self-contained HLO module:

    train_step : (params, opt_state, batch, key) -> (params', opt_state',
                                                     loss, key')

The Rust coordinator (L3) treats (params, opt_state) as an opaque ordered
buffer list that round-trips through the device via `execute_b`; only
`loss` is ever copied back to the host (and only every k steps).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from compile import model as M


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 1e-3
    beta1: float = 0.9
    beta2: float = 0.98
    adam_eps: float = 1e-9
    clip_norm: float = 1.0
    warmup_steps: int = 1000


def init_opt_state(params) -> Dict[str, Any]:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {
        "step": jnp.zeros((), jnp.float32),
        "m": zeros,
        "v": jax.tree_util.tree_map(jnp.zeros_like, params),
    }


def _global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(g * g) for g in leaves) + 1e-12)


def adam_update(params, grads, state, opt: OptConfig):
    """One Adam step with global-norm clipping and linear warmup."""
    step = state["step"] + 1.0
    warm = jnp.minimum(1.0, step / max(opt.warmup_steps, 1))
    lr = opt.lr * warm

    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, opt.clip_norm / gnorm)
    grads = jax.tree_util.tree_map(lambda g: g * scale, grads)

    m = jax.tree_util.tree_map(
        lambda m_, g: opt.beta1 * m_ + (1 - opt.beta1) * g, state["m"], grads
    )
    v = jax.tree_util.tree_map(
        lambda v_, g: opt.beta2 * v_ + (1 - opt.beta2) * g * g,
        state["v"], grads,
    )
    mhat = jax.tree_util.tree_map(lambda m_: m_ / (1 - opt.beta1**step), m)
    vhat = jax.tree_util.tree_map(lambda v_: v_ / (1 - opt.beta2**step), v)
    new_params = jax.tree_util.tree_map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + opt.adam_eps),
        params, mhat, vhat,
    )
    return new_params, {"step": step, "m": m, "v": v}


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------


def _ce(logits, labels):
    """Mean cross-entropy over the batch; labels int32 (B,)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def _lm_ce(logits, targets, loss_mask):
    """Masked next-token cross-entropy; returns (mean loss, token count)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * loss_mask
    count = jnp.sum(loss_mask)
    return jnp.sum(nll) / (count + 1e-6), count


def loss_fn(params, batch, rng_key, cfg: M.ModelConfig, plan):
    """Task-dispatching loss. `batch` is a dict of int32 arrays."""
    if cfg.task == "cls":
        logits = M.cls_logits(
            params, batch["tokens"], batch["mask"], rng_key, cfg, plan
        )
        return _ce(logits, batch["labels"]), logits
    if cfg.task == "retrieval":
        logits = M.retrieval_logits(
            params, batch["tokens1"], batch["mask1"],
            batch["tokens2"], batch["mask2"], rng_key, cfg, plan,
        )
        return _ce(logits, batch["labels"]), logits
    # lm: teacher-forced next-token prediction on the target span.
    logits = M.lm_logits(params, batch["tokens"], rng_key, cfg, plan)
    loss, _ = _lm_ce(
        logits[:, :-1, :], batch["tokens"][:, 1:], batch["loss_mask"][:, 1:]
    )
    return loss, logits


# ---------------------------------------------------------------------------
# lowered entry points
# ---------------------------------------------------------------------------


def train_step(params, opt_state, batch, key, cfg: M.ModelConfig, plan,
               opt: OptConfig):
    """One optimization step; pure, AOT-lowerable."""
    step_key, next_key = jax.random.split(key)
    (loss, _), grads = jax.value_and_grad(
        lambda p: loss_fn(p, batch, step_key, cfg, plan), has_aux=True
    )(params)
    new_params, new_state = adam_update(params, grads, opt_state, opt)
    return new_params, new_state, loss, next_key


def eval_step(params, batch, key, cfg: M.ModelConfig, plan):
    """Loss + correct-prediction count (cls/retrieval) or token NLL (lm)."""
    loss, logits = loss_fn(params, batch, key, cfg, plan)
    if cfg.task in ("cls", "retrieval"):
        pred = jnp.argmax(logits, axis=-1)
        correct = jnp.sum((pred == batch["labels"]).astype(jnp.float32))
        return loss, correct
    # lm: return (mean token nll, target token count) for perplexity.
    _, count = _lm_ce(
        logits[:, :-1, :], batch["tokens"][:, 1:], batch["loss_mask"][:, 1:]
    )
    return loss, count


def generate(params, prompt_tokens, prompt_len, key, cfg: M.ModelConfig,
             plan, max_new: int):
    """Greedy decode for BLEU (Fig 3): fixed-length scan rollout.

    prompt_tokens: (B, n) with the source prefix in positions < prompt_len
    and padding after. Each scan step re-runs the full causal forward and
    writes argmax(logits[pos-1]) at `pos` — O(max_new * forward), fine at
    toy scale and fully static for AOT.
    """
    b, n = prompt_tokens.shape

    def step(carry, i):
        toks, k = carry
        k, sub = jax.random.split(k)
        logits = M.lm_logits(params, toks, sub, cfg, plan)
        pos = prompt_len + i  # scalar: write position for every row
        nxt = jnp.argmax(logits[:, pos - 1, :], axis=-1).astype(toks.dtype)
        keep = (pos < n).astype(toks.dtype)
        col = jnp.clip(pos, 0, n - 1)
        upd = toks.at[:, col].set(keep * nxt + (1 - keep) * toks[:, col])
        return (upd, k), None

    (out, _), _ = jax.lax.scan(
        step, (prompt_tokens, key), jnp.arange(max_new)
    )
    return out
