"""Maclaurin coefficient library for the dot-product kernels of Table 1.

Each kernel K(t) = sum_{N>=0} a_N t^N must have non-negative Maclaurin
coefficients (Kar & Karnick 2012, Lemma 7; Schoenberg 1942, Thm 2) for the
Random Maclaurin Feature (RMF) construction to be an unbiased estimator.

Paper Table 1 (with two typos fixed, validated numerically in
python/tests/test_maclaurin.py and rust/src/reference/maclaurin.rs):

  exp    exp(t)              a_N = 1/N!
  inv    1/(1-t)             a_N = 1
  log    1 - log(1-t)        a_0 = 1, a_N = 1/N          (paper: 1/min(1,N))
  trigh  sinh(t)+cosh(t)     a_N = 1/N!                  (== exp)
  sqrt   2 - sqrt(1-t)       a_0 = 1, a_N = (2N-3)!!/(2^N N!)
                                                         (paper: max(1,2N-3))

`trigh` is algebraically identical to `exp`; it is kept as a separate named
kernel because the paper reports it as a separate row in Table 2 (the RMF
draws differ by seed stream, so trained models differ run-to-run).

The domain of inv/log/sqrt requires |t| < 1 (<= 1 for sqrt); the ppSBN
pre-stage guarantees q.k in [-1, 1] by mapping Q, K into the l2 unit ball.
"""

from __future__ import annotations

import math
from typing import Callable, Dict, List

import numpy as np

KERNELS = ("exp", "inv", "log", "trigh", "sqrt")

#: Truncation degree for static lowering. P[N > 8] = 2^-10 < 0.1% for p=2,
#: and a_N p^{N+1} for the kernels above decays at least as fast as 1/N!
#: except inv/log, whose tail contributes < 2^-9 of the kernel value on the
#: ppSBN-constrained domain |t| <= 1.
DEFAULT_MAX_DEGREE = 8


def _double_factorial(n: int) -> int:
    """(n)!! with the convention (-1)!! = (0)!! = 1."""
    if n <= 0:
        return 1
    out = 1
    while n > 1:
        out *= n
        n -= 2
    return out


def coefficient(kernel: str, n: int) -> float:
    """a_N: the N-th Maclaurin coefficient of the named kernel."""
    if n < 0:
        raise ValueError(f"degree must be >= 0, got {n}")
    if kernel in ("exp", "trigh"):
        return 1.0 / math.factorial(n)
    if kernel == "inv":
        return 1.0
    if kernel == "log":
        return 1.0 if n == 0 else 1.0 / n
    if kernel == "sqrt":
        if n == 0:
            return 1.0
        return _double_factorial(2 * n - 3) / (2.0**n * math.factorial(n))
    raise ValueError(f"unknown kernel {kernel!r}; expected one of {KERNELS}")


def coefficients(kernel: str, max_degree: int) -> List[float]:
    """[a_0, ..., a_max_degree] for the named kernel."""
    return [coefficient(kernel, n) for n in range(max_degree + 1)]


def kernel_fn(kernel: str) -> Callable[[np.ndarray], np.ndarray]:
    """The closed-form K(t) for the named kernel (numpy, elementwise).

    Used only by tests/benchmarks as ground truth; the model side always
    goes through the Maclaurin expansion.
    """
    if kernel in ("exp", "trigh"):
        return np.exp
    if kernel == "inv":
        return lambda t: 1.0 / (1.0 - t)
    if kernel == "log":
        return lambda t: 1.0 - np.log1p(-t)
    if kernel == "sqrt":
        return lambda t: 2.0 - np.sqrt(1.0 - t)
    raise ValueError(f"unknown kernel {kernel!r}; expected one of {KERNELS}")


def truncated_kernel_value(kernel: str, t: float, max_degree: int) -> float:
    """sum_{N=0}^{max_degree} a_N t^N — what the truncated RMF estimates."""
    return float(sum(coefficient(kernel, n) * t**n for n in range(max_degree + 1)))


def degree_distribution(p: float, max_degree: int) -> np.ndarray:
    """P[N = eta] = p^-(eta+1), renormalized over the truncation window.

    The paper samples N from the untruncated geometric law; we truncate at
    `max_degree` so the feature map has a static shape for AOT lowering and
    renormalize so the probabilities still sum to one (the induced bias is
    below the a_N tail bound documented at DEFAULT_MAX_DEGREE).
    """
    if p <= 1.0:
        raise ValueError(f"p must be > 1, got {p}")
    raw = np.array([p ** -(eta + 1) for eta in range(max_degree + 1)], dtype=np.float64)
    return raw / raw.sum()


def sample_degrees(
    num_features: int, p: float, max_degree: int, seed: int
) -> np.ndarray:
    """Draw the per-feature Maclaurin degree N_i for i in [D].

    Sampled at lowering time (numpy, fixed seed) so the degree *buckets*
    are static in the compiled artifact — the MXU-friendly formulation from
    DESIGN.md: features of equal degree form dense matmul chains instead of
    ragged per-feature loops. The Rademacher directions omega remain
    in-graph (redrawn per step from the threaded PRNG key).
    """
    probs = degree_distribution(p, max_degree)
    rng = np.random.default_rng(seed)
    return rng.choice(max_degree + 1, size=num_features, p=probs).astype(np.int32)


def feature_scales(kernel: str, degrees: np.ndarray, p: float) -> np.ndarray:
    """sqrt(a_N * p^(N+1)) per feature — the phi_i prefactor from Def. 3."""
    return np.array(
        [math.sqrt(coefficient(kernel, int(n)) * p ** (int(n) + 1)) for n in degrees],
        dtype=np.float32,
    )


def degree_buckets(degrees: np.ndarray) -> Dict[int, np.ndarray]:
    """Group feature indices by degree: {N: indices with degree N}."""
    out: Dict[int, np.ndarray] = {}
    for n in np.unique(degrees):
        out[int(n)] = np.nonzero(degrees == n)[0].astype(np.int32)
    return out
