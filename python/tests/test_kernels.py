"""L1 Pallas kernels vs the pure-jnp oracle — the CORE correctness signal.

Hypothesis sweeps shapes; fixed-seed cases pin the exact numerics.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import maclaurin
from compile.kernels import ref, rmf, rmfa, softmax_attn

SET = dict(max_examples=12, deadline=None)


def _rand(key, shape, scale=0.5):
    return jax.random.normal(key, shape, jnp.float32) * scale


def _bucket_setup(kernel, D, dh, max_deg=6, seed=11):
    degrees = maclaurin.sample_degrees(D, 2.0, max_deg, seed=seed)
    scales = maclaurin.feature_scales(kernel, degrees, 2.0)
    buckets = maclaurin.degree_buckets(degrees)
    omega = ref.sample_omega(jax.random.PRNGKey(seed), D, max_deg, dh)
    bo, bs = [], []
    perm = []
    for eta, idx in sorted(buckets.items()):
        W = jnp.transpose(omega[idx, :eta, :], (1, 2, 0))
        bo.append((int(eta), W))
        bs.append(jnp.asarray(scales[idx]))
        perm.extend(idx.tolist())
    return omega, degrees, scales, bo, bs, np.array(perm)


# ---------------------------------------------------------------------------
# RMF projection
# ---------------------------------------------------------------------------


@settings(**SET)
@given(
    rows=st.integers(3, 40),
    dh=st.sampled_from([4, 8, 16]),
    D=st.sampled_from([8, 32, 64]),
    kernel=st.sampled_from(list(maclaurin.KERNELS)),
)
def test_rmf_pallas_matches_bucketed_ref(rows, dh, D, kernel):
    _, _, _, bo, bs, _ = _bucket_setup(kernel, D, dh)
    x = _rand(jax.random.PRNGKey(rows), (rows, dh))
    got = rmf.rmf_features_pallas(x, bo, bs, block_m=16)
    want = ref.rmf_features_bucketed(x, bo, bs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=1e-5)


def test_rmf_bucketed_is_permutation_of_direct():
    omega, degrees, scales, bo, bs, perm = _bucket_setup("exp", 32, 8)
    x = _rand(jax.random.PRNGKey(0), (10, 8))
    direct = np.asarray(ref.rmf_features(x, omega, degrees, scales))
    bucketed = np.asarray(ref.rmf_features_bucketed(x, bo, bs))
    np.testing.assert_allclose(bucketed, direct[:, perm], rtol=1e-4, atol=1e-6)


def test_rmf_handles_ragged_row_count():
    # rows not divisible by block_m exercises the padding path
    _, _, _, bo, bs, _ = _bucket_setup("inv", 16, 4)
    x = _rand(jax.random.PRNGKey(1), (37, 4))
    got = rmf.rmf_features_pallas(x, bo, bs, block_m=16)
    want = ref.rmf_features_bucketed(x, bo, bs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=1e-5)


def test_rmf_gradients_flow():
    _, _, _, bo, bs, _ = _bucket_setup("exp", 16, 4)
    x = _rand(jax.random.PRNGKey(2), (8, 4))

    def f(x):
        return jnp.sum(rmf.rmf_features_pallas(x, bo, bs, block_m=8) ** 2)

    g = jax.grad(f)(x)
    assert g.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(g)))
    # finite-difference check on one coordinate
    eps = 1e-3
    x2 = x.at[0, 0].add(eps)
    fd = (f(x2) - f(x)) / eps
    assert float(fd) == pytest.approx(float(g[0, 0]), rel=0.05, abs=1e-2)


# ---------------------------------------------------------------------------
# linear attention contraction
# ---------------------------------------------------------------------------


@settings(**SET)
@given(
    g=st.integers(1, 4),
    n=st.sampled_from([16, 32, 64]),
    D=st.sampled_from([8, 16]),
    d=st.sampled_from([4, 8]),
)
def test_linear_attn_bidir_matches_ref(g, n, D, d):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(n * 7 + D), 3)
    phi_q = jnp.abs(_rand(k1, (g, n, D), 1.0))
    phi_k = jnp.abs(_rand(k2, (g, n, D), 1.0))
    v = _rand(k3, (g, n, d), 1.0)
    got = rmfa.linear_attn_bidir(phi_q, phi_k, v, 1e-6, 16, True)
    want = ref.linear_attn_ref(phi_q, phi_k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-4)


@settings(**SET)
@given(n=st.sampled_from([16, 32, 64]), bn=st.sampled_from([8, 16]))
def test_linear_attn_causal_matches_ref(n, bn):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(n + bn), 3)
    phi_q = jnp.abs(_rand(k1, (2, n, 12), 1.0))
    phi_k = jnp.abs(_rand(k2, (2, n, 12), 1.0))
    v = _rand(k3, (2, n, 6), 1.0)
    got = rmfa.linear_attn_causal(phi_q, phi_k, v, 1e-6, bn, True)
    want = ref.linear_attn_ref(phi_q, phi_k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-3, atol=5e-4)


def test_linear_attn_gradients_match_ref():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(5), 3)
    phi_q = jnp.abs(_rand(k1, (1, 32, 8), 1.0))
    phi_k = jnp.abs(_rand(k2, (1, 32, 8), 1.0))
    v = _rand(k3, (1, 32, 4), 1.0)

    def f_pallas(pq, pk, vv):
        return jnp.sum(rmfa.linear_attn_bidir(pq, pk, vv, 1e-6, 16, True) ** 2)

    def f_ref(pq, pk, vv):
        return jnp.sum(ref.linear_attn_ref(pq, pk, vv) ** 2)

    gp = jax.grad(f_pallas, argnums=(0, 1, 2))(phi_q, phi_k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(phi_q, phi_k, v)
    for a, b in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-3, atol=5e-4)


def test_linear_attn_causal_gradients_match_ref():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(6), 3)
    phi_q = jnp.abs(_rand(k1, (1, 16, 8), 1.0))
    phi_k = jnp.abs(_rand(k2, (1, 16, 8), 1.0))
    v = _rand(k3, (1, 16, 4), 1.0)

    def f_pallas(pq, pk, vv):
        return jnp.sum(rmfa.linear_attn_causal(pq, pk, vv, 1e-6, 8, True) ** 2)

    def f_ref(pq, pk, vv):
        return jnp.sum(ref.linear_attn_ref(pq, pk, vv, causal=True) ** 2)

    gp = jax.grad(f_pallas, argnums=(0, 1, 2))(phi_q, phi_k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(phi_q, phi_k, v)
    for a, b in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-3, atol=5e-4)


def test_key_mask_removes_padded_keys():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(7), 3)
    B, H, n, D, d = 2, 1, 16, 8, 4
    phi_q = jnp.abs(_rand(k1, (B, H, n, D), 1.0))
    phi_k = jnp.abs(_rand(k2, (B, H, n, D), 1.0))
    v = _rand(k3, (B, H, n, d), 1.0)
    mask = jnp.concatenate([jnp.ones((B, 10), jnp.int32), jnp.zeros((B, 6), jnp.int32)], 1)
    masked = ref.linear_attn_ref(phi_q, phi_k, v, key_mask=mask)
    # equivalent: physically truncate the keys
    trunc = ref.linear_attn_ref(phi_q, phi_k[:, :, :10], v[:, :, :10])
    np.testing.assert_allclose(np.asarray(masked), np.asarray(trunc), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# softmax attention baseline
# ---------------------------------------------------------------------------


@settings(**SET)
@given(
    g=st.integers(1, 4),
    n=st.sampled_from([16, 32, 64]),
    d=st.sampled_from([8, 16]),
    causal=st.booleans(),
)
def test_softmax_attn_pallas_matches_ref(g, n, d, causal):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(g * 100 + n + d), 3)
    q = _rand(k1, (g, n, d), 1.0)
    k = _rand(k2, (g, n, d), 1.0)
    v = _rand(k3, (g, n, d), 1.0)
    got = softmax_attn.softmax_attn(q, k, v, None, causal, 16, 16, True)
    want = ref.softmax_attn_ref(
        q[:, None], k[:, None], v[:, None], causal=causal
    )[:, 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-5)


def test_softmax_attn_key_bias_masks_keys():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(8), 3)
    g, n, d = 2, 32, 8
    q, k, v = _rand(k1, (g, n, d)), _rand(k2, (g, n, d)), _rand(k3, (g, n, d))
    bias = jnp.concatenate(
        [jnp.zeros((g, 20), jnp.float32), jnp.full((g, 12), -1e9, jnp.float32)], 1
    )
    got = softmax_attn.softmax_attn(q, k, v, bias, False, 16, 16, True)
    mask = jnp.concatenate([jnp.ones((g, 20), jnp.int32), jnp.zeros((g, 12), jnp.int32)], 1)
    want = ref.softmax_attn_ref(q[:, None], k[:, None], v[:, None], key_mask=mask)[:, 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-5)


def test_softmax_attn_gradients_match_ref():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(9), 3)
    g, n, d = 1, 32, 8
    q, k, v = _rand(k1, (g, n, d)), _rand(k2, (g, n, d)), _rand(k3, (g, n, d))

    def f_pallas(q, k, v):
        return jnp.sum(softmax_attn.softmax_attn(q, k, v, None, False, 16, 16, True) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(ref.softmax_attn_ref(q[:, None], k[:, None], v[:, None])[:, 0] ** 2)

    gp = jax.grad(f_pallas, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# end-to-end approximation quality (Theorems 1-2 at kernel granularity)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kernel", maclaurin.KERNELS)
def test_rmfa_expectation_approaches_truncated_kernelized_attn(kernel):
    B, H, n, dh, D, maxdeg = 1, 1, 24, 8, 48, 6
    key = jax.random.PRNGKey(17)
    kq, kk, kv = jax.random.split(key, 3)
    # ppSBN-style domain: rows in the unit ball
    q = _rand(kq, (B, H, n, dh), 0.3)
    k = _rand(kk, (B, H, n, dh), 0.3)
    v = _rand(kv, (B, H, n, dh), 1.0)
    degrees = maclaurin.sample_degrees(D, 2.0, maxdeg, seed=5)
    scales = maclaurin.feature_scales(kernel, degrees, 2.0)
    outs = []
    for s in range(24):
        omega = ref.sample_omega(jax.random.PRNGKey(100 + s), D, maxdeg, dh)
        outs.append(np.asarray(ref.rmfa_ref(q, k, v, omega, degrees, scales)))
    approx = np.mean(outs, axis=0)
    exact = np.asarray(
        ref.truncated_kernelized_attn_ref(q, k, v, kernel, maxdeg)
    )
    err = np.mean((approx - exact) ** 2) / np.mean(exact**2)
    assert err < 0.05, f"{kernel}: NMSE {err}"


def test_rmfa_error_decreases_with_D():
    B, H, n, dh, maxdeg = 1, 1, 16, 8, 6
    key = jax.random.PRNGKey(3)
    kq, kk, kv = jax.random.split(key, 3)
    q = _rand(kq, (B, H, n, dh), 0.3)
    k = _rand(kk, (B, H, n, dh), 0.3)
    v = _rand(kv, (B, H, n, dh), 1.0)
    exact = np.asarray(ref.truncated_kernelized_attn_ref(q, k, v, "exp", maxdeg))

    def err_at(D):
        degrees = maclaurin.sample_degrees(D, 2.0, maxdeg, seed=5)
        scales = maclaurin.feature_scales("exp", degrees, 2.0)
        errs = []
        for s in range(12):
            omega = ref.sample_omega(jax.random.PRNGKey(s), D, maxdeg, dh)
            out = np.asarray(ref.rmfa_ref(q, k, v, omega, degrees, scales))
            errs.append(np.mean((out - exact) ** 2) / np.mean(exact**2))
        return float(np.mean(errs))

    assert err_at(256) < err_at(16) / 2
