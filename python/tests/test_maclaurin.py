"""Table-1 coefficient library: closed forms vs expansions, degree law."""

import math

import numpy as np
import pytest

from compile import maclaurin


@pytest.mark.parametrize("kernel", maclaurin.KERNELS)
def test_coefficients_nonnegative(kernel):
    for n in range(16):
        assert maclaurin.coefficient(kernel, n) >= 0.0


@pytest.mark.parametrize("kernel", maclaurin.KERNELS)
@pytest.mark.parametrize("t", [-0.5, -0.2, 0.0, 0.1, 0.3, 0.6, 0.9])
def test_expansion_matches_closed_form(kernel, t):
    # inv/log converge geometrically in |t|: a degree-18 truncation at
    # t=0.9 is ~0.9^19 away for exp-type kernels but ~13% for 1/(1-t);
    # scale the degree with the distance to the domain edge.
    degree = 18 if abs(t) <= 0.6 else 60
    fn = maclaurin.kernel_fn(kernel)
    exact = float(fn(np.array(t)))
    series = maclaurin.truncated_kernel_value(kernel, t, degree)
    assert series == pytest.approx(exact, rel=2e-2, abs=1e-3)


def test_exp_and_trigh_identical():
    # sinh + cosh == exp: both rows of Table 1 share coefficients
    for n in range(12):
        assert maclaurin.coefficient("exp", n) == maclaurin.coefficient("trigh", n)


def test_known_coefficient_values():
    assert maclaurin.coefficient("exp", 3) == pytest.approx(1 / 6)
    assert maclaurin.coefficient("inv", 7) == 1.0
    assert maclaurin.coefficient("log", 0) == 1.0
    assert maclaurin.coefficient("log", 4) == pytest.approx(1 / 4)
    # sqrt: a_4 = (2*4-3)!!/(2^4 4!) = 15/384, NOT the paper's literal
    # max(1,2N-3)/(2^N N!) = 5/384 (typo; the series test above would fail)
    assert maclaurin.coefficient("sqrt", 4) == pytest.approx(15 / 384)


def test_degree_distribution_normalized_and_geometric():
    for p in [1.5, 2.0, 3.0]:
        probs = maclaurin.degree_distribution(p, 8)
        assert probs.sum() == pytest.approx(1.0)
        ratios = probs[:-1] / probs[1:]
        np.testing.assert_allclose(ratios, p, rtol=1e-9)


def test_degree_distribution_rejects_bad_p():
    with pytest.raises(ValueError):
        maclaurin.degree_distribution(1.0, 8)


def test_sample_degrees_distribution():
    degrees = maclaurin.sample_degrees(20000, 2.0, 8, seed=0)
    probs = maclaurin.degree_distribution(2.0, 8)
    counts = np.bincount(degrees, minlength=9) / len(degrees)
    np.testing.assert_allclose(counts, probs, atol=0.01)


def test_feature_scale_recovers_coefficient():
    # scale^2 * p^-(N+1) == a_N (the untruncated-law telescoping identity)
    degrees = np.array([0, 1, 2, 5], dtype=np.int32)
    for kernel in maclaurin.KERNELS:
        scales = maclaurin.feature_scales(kernel, degrees, 2.0)
        for d, s in zip(degrees, scales):
            back = float(s) ** 2 * 2.0 ** -(int(d) + 1)
            assert back == pytest.approx(maclaurin.coefficient(kernel, int(d)), rel=1e-5)


def test_degree_buckets_partition():
    degrees = maclaurin.sample_degrees(256, 2.0, 8, seed=3)
    buckets = maclaurin.degree_buckets(degrees)
    total = sum(len(v) for v in buckets.values())
    assert total == 256
    for eta, idx in buckets.items():
        assert np.all(degrees[idx] == eta)


def test_unknown_kernel_raises():
    with pytest.raises(ValueError):
        maclaurin.coefficient("gauss", 1)
    with pytest.raises(ValueError):
        maclaurin.kernel_fn("gauss")
