"""L2 model family: shapes, masking, gradients, variant coverage."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import train as T


def _cfg(**kw):
    base = dict(
        attn="mac_exp", seq_len=64, vocab_size=50, task="cls",
        feature_dim=32, num_classes=2, attn_block_n=32, use_pallas=True,
    )
    base.update(kw)
    return M.ModelConfig(**base).validate()


def _plan(cfg):
    return M.make_rmf_plan(cfg) if cfg.kernel_name else None


@pytest.mark.parametrize("attn", M.ATTN_VARIANTS)
def test_cls_logits_shape_all_variants(attn):
    cfg = _cfg(attn=attn, ppsbn=attn.startswith("mac_"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jnp.zeros((3, 64), jnp.int32)
    mask = jnp.ones((3, 64), jnp.int32)
    logits = M.cls_logits(params, tokens, mask, jax.random.PRNGKey(1), cfg, _plan(cfg))
    assert logits.shape == (3, 2)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_param_count_matches_manual():
    cfg = _cfg(attn="softmax", ppsbn=False)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    n = M.count_params(params)
    d, ff, vocab, seq = 64, 128, 50, 64
    per_layer = 4 * (d * d + d) + (d * ff + ff) + (ff * d + d) + 4 * d
    expected = vocab * d + seq * d + 2 * d + 2 * per_layer + (d * 2 + 2)
    assert n == expected


def test_ppsbn_adds_trainable_scalars():
    a = M.count_params(M.init_params(jax.random.PRNGKey(0), _cfg(attn="softmax", ppsbn=False)))
    b = M.count_params(M.init_params(jax.random.PRNGKey(0), _cfg(attn="softmax", ppsbn=True)))
    # gamma + beta per head per layer: 2 layers x 2 heads x 2 = 8
    assert b - a == 8


def test_padding_mask_blocks_information():
    """Changing tokens at masked positions must not change cls logits."""
    cfg = _cfg(attn="mac_exp", ppsbn=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(5)
    tokens = jnp.ones((2, 64), jnp.int32)
    mask = jnp.concatenate([jnp.ones((2, 40), jnp.int32), jnp.zeros((2, 24), jnp.int32)], 1)
    a = M.cls_logits(params, tokens, mask, key, cfg, _plan(cfg))
    tokens2 = tokens.at[:, 45:].set(7)
    b = M.cls_logits(params, tokens2, mask, key, cfg, _plan(cfg))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=1e-5)


def test_causal_lm_is_autoregressive():
    """Future tokens must not influence earlier positions' logits."""
    cfg = _cfg(attn="mac_exp", task="lm", causal=True, ppsbn=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(6)
    toks = jnp.ones((1, 64), jnp.int32)
    a = M.lm_logits(params, toks, key, cfg, _plan(cfg))
    toks2 = toks.at[0, 50:].set(9)
    b = M.lm_logits(params, toks2, key, cfg, _plan(cfg))
    # positions strictly before 50 see identical logits.
    # NOTE: preSBN uses batch statistics over the whole sequence, which
    # would leak future info; the causal LM config therefore must compute
    # identical outputs only when ppSBN stats are stable — we check the
    # causal-attention property via the no-ppsbn config instead.
    cfg2 = _cfg(attn="mac_exp", task="lm", causal=True, ppsbn=False)
    params2 = M.init_params(jax.random.PRNGKey(0), cfg2)
    a = M.lm_logits(params2, toks, key, cfg2, _plan(cfg2))
    b = M.lm_logits(params2, toks2, key, cfg2, _plan(cfg2))
    np.testing.assert_allclose(
        np.asarray(a[:, :49]), np.asarray(b[:, :49]), rtol=2e-4, atol=1e-5
    )


def test_retrieval_head_is_symmetric_in_weights():
    cfg = _cfg(attn="mac_inv", task="retrieval", ppsbn=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(7)
    t1 = jnp.ones((2, 64), jnp.int32)
    t2 = jnp.full((2, 64), 3, jnp.int32)
    m = jnp.ones((2, 64), jnp.int32)
    out = M.retrieval_logits(params, t1, m, t2, m, key, cfg, _plan(cfg))
    assert out.shape == (2, 2)


@pytest.mark.parametrize("attn", ["softmax", "rfa", "mac_exp", "mac_log"])
def test_gradients_nonzero_for_all_param_groups(attn):
    cfg = _cfg(attn=attn, ppsbn=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = {
        "tokens": jnp.ones((2, 64), jnp.int32),
        "mask": jnp.ones((2, 64), jnp.int32),
        "labels": jnp.array([0, 1], jnp.int32),
    }

    def loss(p):
        return T.loss_fn(p, batch, jax.random.PRNGKey(1), cfg, _plan(cfg))[0]

    g = jax.grad(loss)(params)
    flat, _ = jax.tree_util.tree_flatten(g)
    finite = all(bool(jnp.all(jnp.isfinite(x))) for x in flat)
    assert finite
    nonzero = sum(float(jnp.sum(jnp.abs(x))) > 0 for x in flat)
    # the vast majority of parameter groups must receive gradient
    assert nonzero >= len(flat) - 4, f"{nonzero}/{len(flat)} groups with grad"


def test_use_pallas_false_matches_true():
    """The pure-jnp fallback and the Pallas path are the same function."""
    key = jax.random.PRNGKey(8)
    tokens = jnp.ones((2, 64), jnp.int32)
    mask = jnp.ones((2, 64), jnp.int32)
    outs = []
    for pallas in [True, False]:
        cfg = _cfg(attn="mac_exp", use_pallas=pallas, ppsbn=True)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        outs.append(
            np.asarray(
                M.cls_logits(params, tokens, mask, key, cfg, _plan(cfg))
            )
        )
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-3, atol=2e-4)


def test_rmf_plan_static_and_deterministic():
    cfg = _cfg(attn="mac_sqrt")
    p1 = M.make_rmf_plan(cfg)
    p2 = M.make_rmf_plan(cfg)
    assert p1 == p2
    assert sum(p1.bucket_sizes) == cfg.feature_dim
    assert len(p1.degrees) == cfg.feature_dim


def test_config_validation_rejects_bad_input():
    with pytest.raises(ValueError):
        M.ModelConfig(attn="nope").validate()
    with pytest.raises(ValueError):
        M.ModelConfig(task="nope").validate()
    with pytest.raises(ValueError):
        M.ModelConfig(attn="rfa", feature_dim=33).validate()
