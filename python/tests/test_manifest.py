"""Artifact manifest self-check: the python->rust contract."""

import json
import os
import re

import pytest

ART = os.environ.get(
    "MACFORMER_ARTIFACTS",
    os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"),
)


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


def test_all_table2_cells_present(manifest):
    names = {m["name"] for m in manifest["modules"]}
    for task in ["lra_text", "lra_listops", "lra_retrieval"]:
        for variant in ["softmax", "rfa", "mac_exp", "mac_inv", "mac_trigh",
                        "mac_log", "mac_sqrt"]:
            for role in ["init", "train", "eval"]:
                assert f"{task}.{variant}.{role}" in names


def test_fig3_families_present(manifest):
    names = {m["name"] for m in manifest["modules"]}
    for suffix in ["base", "ppsbn"]:
        for role in ["init", "train", "eval", "generate"]:
            assert f"translation.softmax.{suffix}.{role}" in names


def test_micro_grid_present(manifest):
    names = {m["name"] for m in manifest["modules"]}
    for n in manifest["micro"]["lengths"]:
        assert f"micro.softmax.n{n}" in names
        for D in manifest["micro"]["features"]:
            assert f"micro.rmfa_exp.n{n}.D{D}" in names


def test_files_exist_and_are_hlo(manifest):
    for m in manifest["modules"]:
        path = os.path.join(ART, m["file"])
        assert os.path.exists(path), m["file"]
        with open(path) as f:
            head = f.read(200)
        assert head.startswith("HloModule"), m["file"]


def test_entry_parameter_counts_match_specs(manifest):
    """The HLO entry signature must agree with the manifest arity —
    guards against jax dropping unused args (keep_unused regression)."""
    for m in manifest["modules"]:
        path = os.path.join(ART, m["file"])
        with open(path) as f:
            text = f.read()
        entry = text[text.rindex("ENTRY"):]
        n_params = len(set(re.findall(r"parameter\((\d+)\)", entry)))
        role = m["role"]
        if role == "init":
            expected = 1
        elif role == "train":
            expected = m["n_params"] + m["n_opt"] + len(m["batch_specs"]) + 1
        elif role == "eval":
            expected = m["n_params"] + len(m["batch_specs"]) + 1
        elif role == "generate":
            expected = m["n_params"] + 2
        elif role == "micro_softmax":
            expected = 3
        elif role == "micro_rmfa":
            expected = 4
        else:
            continue
        assert n_params == expected, f"{m['name']}: {n_params} vs {expected}"


def test_state_specs_consistent(manifest):
    for m in manifest["modules"]:
        if m["role"] != "train":
            continue
        assert len(m["param_specs"]) == m["n_params"], m["name"]
        assert len(m["opt_specs"]) == m["n_opt"], m["name"]
        for spec in m["param_specs"] + m["opt_specs"]:
            assert spec["dtype"] == "float32", m["name"]


def test_manifest_hash_tracks_sources(manifest):
    assert re.fullmatch(r"[0-9a-f]{16}", manifest["input_hash"])
