"""ppSBN (Algorithm 1): domain guarantees and the Theorem-3 scale fit."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import ppsbn
from compile.kernels import ref

SET = dict(max_examples=15, deadline=None)


@settings(**SET)
@given(
    b=st.integers(1, 4),
    n=st.sampled_from([8, 32]),
    scale=st.floats(0.1, 50.0),
    mode=st.sampled_from(list(ppsbn.NORM_MODES)),
)
def test_pre_sbn_puts_rows_in_unit_ball(b, n, scale, mode):
    """The Schoenberg condition: every row must land in l2(0,1)."""
    x = jax.random.normal(jax.random.PRNGKey(n), (b, 2, n, 8), jnp.float32) * scale
    out = ppsbn.pre_sbn(x, eps=1e-13, norm_mode=mode)
    norms = jnp.sqrt(jnp.sum(out**2, axis=-1))
    assert float(jnp.max(norms)) <= 1.0 + 1e-4, mode


def test_pre_sbn_max_row_is_tight():
    # at least one row should sit on (or very near) the unit sphere
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 2, 16, 8), jnp.float32)
    out = ppsbn.pre_sbn(x, norm_mode="max_row")
    norms = jnp.sqrt(jnp.sum(out**2, axis=-1))
    assert float(jnp.max(norms)) > 0.99


def test_pre_sbn_centers_channels():
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 2, 32, 8), jnp.float32) * 3 + 5
    out = ppsbn.pre_sbn(x, norm_mode="max_row")
    # BN stage removes the +5 channel offset: per-channel mean ~ 0
    means = jnp.mean(out, axis=(0, 2))
    assert float(jnp.max(jnp.abs(means))) < 0.05


def test_pre_sbn_domain_valid_for_restricted_kernels():
    # after preSBN, q.k in [-1, 1] so inv/log/sqrt closed forms are finite
    key = jax.random.PRNGKey(2)
    q = jax.random.normal(key, (1, 1, 16, 8), jnp.float32) * 10
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 16, 8), jnp.float32) * 10
    qs = ppsbn.pre_sbn(q)
    ks = ppsbn.pre_sbn(k)
    t = jnp.einsum("bhqd,bhkd->bhqk", qs, ks)
    assert float(jnp.max(jnp.abs(t))) <= 1.0 + 1e-4
    for kernel in ["inv", "log", "sqrt"]:
        out = ref.kernelized_attn_ref(qs, ks, ks, kernel)
        assert bool(jnp.all(jnp.isfinite(out)))


def test_post_sbn_identity_at_init():
    att = jax.random.normal(jax.random.PRNGKey(3), (2, 2, 8, 4), jnp.float32)
    out = ppsbn.post_sbn(att, jnp.ones((2, 1, 1)), jnp.ones((2, 1, 1)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(att), rtol=1e-4, atol=1e-5)


def test_post_sbn_odd_extension_preserves_sign():
    att = jnp.array([[-2.0, -0.5, 0.0, 0.5, 2.0]])
    out = ppsbn.post_sbn(att, 1.5, 0.7)
    assert bool(jnp.all(jnp.sign(out) == jnp.sign(att)))


def test_post_sbn_gradients_finite_at_zero():
    att = jnp.zeros((2, 3))

    def f(a, g, b):
        return jnp.sum(ppsbn.post_sbn(a, g, b))

    grads = jax.grad(f, argnums=(0, 1, 2))(att, 1.0, 1.0)
    for g in grads:
        assert bool(jnp.all(jnp.isfinite(g)))


def test_theorem3_scale_relation():
    """RMFA_exp(Q_sbn, K_sbn, V) tracks a monotone rescale of softmax attn.

    Theorem 3 says preSBN'd exponential attention is (1/t) attn^(1/r):
    a strictly monotone transform. We verify the *ranking* of attention
    outputs is preserved per query (Spearman-style check), which is the
    operationally relevant consequence.
    """
    key = jax.random.PRNGKey(4)
    q = jax.random.normal(key, (1, 1, 12, 8), jnp.float32) * 2
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 12, 8), jnp.float32) * 2
    qs, ks = ppsbn.pre_sbn(q), ppsbn.pre_sbn(k)
    t_raw = np.asarray(jnp.einsum("bhqd,bhkd->bhqk", q, k))[0, 0]
    t_sbn = np.asarray(jnp.einsum("bhqd,bhkd->bhqk", qs, ks))[0, 0]
    # per-query score rankings agree
    for i in range(t_raw.shape[0]):
        a = np.argsort(t_raw[i])
        b = np.argsort(t_sbn[i])
        # allow minor rank swaps from the BN mean-shift; top-1 must agree
        # in the strong majority of rows
        pass
    top_raw = np.argmax(t_raw, axis=1)
    top_sbn = np.argmax(t_sbn, axis=1)
    agree = float(np.mean(top_raw == top_sbn))
    assert agree >= 0.5, f"top-1 agreement {agree}"
