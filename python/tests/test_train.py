"""Optimizer + train/eval/generate step functions."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import train as T


def _cfg(**kw):
    base = dict(
        attn="mac_exp", seq_len=64, vocab_size=40, task="cls",
        feature_dim=32, num_classes=2, attn_block_n=32,
    )
    base.update(kw)
    return M.ModelConfig(**base).validate()


def _setup(cfg, seed=0):
    plan = M.make_rmf_plan(cfg) if cfg.kernel_name else None
    params = M.init_params(jax.random.PRNGKey(seed), cfg)
    return params, T.init_opt_state(params), plan


def test_adam_decreases_quadratic():
    """Sanity: Adam minimizes a simple quadratic."""
    params = {"w": jnp.array([5.0, -3.0])}
    state = T.init_opt_state(params)
    opt = T.OptConfig(lr=0.1, warmup_steps=1)
    for _ in range(200):
        grads = jax.tree_util.tree_map(lambda w: 2 * w, params)
        params, state = T.adam_update(params, grads, state, opt)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_adam_warmup_scales_first_steps():
    params = {"w": jnp.array([1.0])}
    state = T.init_opt_state(params)
    opt = T.OptConfig(lr=1.0, warmup_steps=100, clip_norm=1e9)
    grads = {"w": jnp.array([1.0])}
    p1, _ = T.adam_update(params, grads, state, opt)
    # step 1 of 100-step warmup: effective lr = 0.01 -> |delta| ~ 0.01
    delta = float(jnp.abs(p1["w"] - params["w"]).max())
    assert delta < 0.02


def test_grad_clipping_bounds_update():
    params = {"w": jnp.zeros((4,))}
    state = T.init_opt_state(params)
    opt = T.OptConfig(lr=0.1, warmup_steps=1, clip_norm=1.0)
    huge = {"w": jnp.full((4,), 1e8)}
    small = {"w": jnp.full((4,), 0.5)}
    p_huge, _ = T.adam_update(params, huge, state, opt)
    p_small, _ = T.adam_update(params, small, state, opt)
    # after clipping, the huge gradient produces a comparable step size
    r = float(jnp.abs(p_huge["w"]).max() / jnp.abs(p_small["w"]).max())
    assert r < 3.0


def test_train_step_reduces_loss_on_fixed_batch():
    cfg = _cfg()
    params, opt_state, plan = _setup(cfg)
    opt = T.OptConfig(lr=3e-3, warmup_steps=1)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 64), 0, 40),
        "mask": jnp.ones((8, 64), jnp.int32),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (8,), 0, 2),
    }
    key = jax.random.PRNGKey(3)
    step = jax.jit(lambda p, s, k: T.train_step(p, s, batch, k, cfg, plan, opt))
    first = None
    for _ in range(15):
        params, opt_state, loss, key = step(params, opt_state, key)
        if first is None:
            first = float(loss)
    assert float(loss) < first * 0.9, f"{first} -> {float(loss)}"


def test_eval_step_counts_correct_predictions():
    cfg = _cfg(attn="softmax", ppsbn=False)
    params, _, plan = _setup(cfg)
    batch = {
        "tokens": jnp.ones((4, 64), jnp.int32),
        "mask": jnp.ones((4, 64), jnp.int32),
        "labels": jnp.zeros((4,), jnp.int32),
    }
    loss, correct = T.eval_step(params, batch, jax.random.PRNGKey(0), cfg, plan)
    assert 0.0 <= float(correct) <= 4.0
    assert float(loss) > 0.0


def test_lm_loss_ignores_unmasked_positions():
    cfg = _cfg(task="lm", causal=True)
    params, _, plan = _setup(cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, 40)
    full = jnp.ones((2, 64), jnp.float32)
    half = full.at[:, :32].set(0.0)
    key = jax.random.PRNGKey(2)
    l_full, _ = T.loss_fn(params, {"tokens": toks, "loss_mask": full}, key, cfg, plan)
    l_half, _ = T.loss_fn(params, {"tokens": toks, "loss_mask": half}, key, cfg, plan)
    assert float(l_full) != pytest.approx(float(l_half), rel=1e-3)


def test_generate_writes_only_after_prompt():
    cfg = _cfg(task="lm", causal=True, vocab_size=40)
    params, _, plan = _setup(cfg)
    prompt = jnp.full((2, 64), 5, jnp.int32)
    out = T.generate(params, prompt, 25, jax.random.PRNGKey(0), cfg, plan, 16)
    out = np.asarray(out)
    # prompt region untouched
    np.testing.assert_array_equal(out[:, :25], 5)
    # generated region was written (any position changed)
    assert np.any(out[:, 25:41] != 5)
    # region past max_new untouched
    np.testing.assert_array_equal(out[:, 41:], 5)


def test_train_step_is_deterministic_given_key():
    cfg = _cfg()
    params, opt_state, plan = _setup(cfg)
    opt = T.OptConfig()
    batch = {
        "tokens": jnp.ones((4, 64), jnp.int32),
        "mask": jnp.ones((4, 64), jnp.int32),
        "labels": jnp.zeros((4,), jnp.int32),
    }
    k = jax.random.PRNGKey(9)
    _, _, l1, _ = T.train_step(params, opt_state, batch, k, cfg, plan, opt)
    _, _, l2, _ = T.train_step(params, opt_state, batch, k, cfg, plan, opt)
    assert float(l1) == float(l2)
